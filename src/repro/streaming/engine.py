"""Scan drivers: thread an O(|V|+k) carry through EdgeStream chunks.

Consumers speak the :class:`~repro.streaming.carry.PartitionerCarry`
protocol (``init / step_chunk / merge / finalize``); :func:`run_carry`
drives one protocol instance over a stream sequentially, and
``repro.streaming.parallel.run_parallel`` drives it over S sharded
sub-streams with carry all-reduces at super-chunk boundaries.

Chunk step functions are jitted by their author (module-level, so the
compile cache is shared across every call with the same chunk shape — the
engine never recompiles per invocation).  ``repro.kernels.stream_scan``
hosts the scoring-baseline carries; ``core.clustering``,
``core.postprocess`` and ``core.cms`` host the other consumers.

``run_scan`` is the legacy ``(carry0, chunk_fn)`` surface, now a thin
:class:`~repro.streaming.carry.FnCarry` adapter over the same driver.
``run_scan_batched`` vmaps one compiled chunk function over a stacked
carry: many seeds, many HDRF λ values, or many (padded) partition counts
run as one batched engine over a single pass of the stream.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .carry import FnCarry, PartitionerCarry
from .stream import DEFAULT_CHUNK, EdgeStream

__all__ = ["as_stream", "run_carry", "run_retract", "run_scan",
           "run_scan_batched"]


def as_stream(src, dst, n_vertices=None, *, stream=None, chunk_size=None):
    """Normalize (arrays | existing stream) to an :class:`EdgeStream`.

    The shared front door of every streaming consumer: pass ``stream`` to
    reuse a replayable (possibly out-of-core / reordered) stream, or raw
    arrays to wrap them at ``chunk_size``.
    """
    if stream is not None:
        return stream
    return EdgeStream(src, dst, n_vertices,
                      chunk_size=chunk_size or DEFAULT_CHUNK)


def run_carry(stream: EdgeStream, pc: PartitionerCarry, *extras, carry=None):
    """Drive a PartitionerCarry over every chunk of ``stream``.

    Returns ``(parts, result)`` where ``parts`` is in arrival order
    (stream-order results are scattered back through the stream's
    permutation) — ``None`` for state-only consumers — and ``result`` is
    ``pc.finalize(final_carry)``.
    """
    if carry is None:
        carry = pc.init()
    outs = []
    for ch in stream.chunks(*extras):
        carry, parts = pc.step_chunk(
            carry, ch.src, ch.dst, jnp.int32(ch.n_valid), *ch.extras)
        if parts is not None:
            outs.append(parts[: ch.n_valid])
    result = pc.finalize(carry)
    if not outs:
        return None, result
    parts = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
    return stream.scatter_back(parts), result


def run_retract(stream: EdgeStream, pc: PartitionerCarry, parts, *extras,
                carry, num_streams: int = 1, super_chunk: int = 8,
                shard: str = "range", backend=None, mesh=None):
    """Drive ``pc.retract_chunk`` over every chunk of ``stream``.

    The inverse-direction driver of :func:`run_carry`: ``stream`` holds
    the edges being **deleted**, ``parts`` their recorded per-edge results
    (``None`` for state-only consumers), and ``carry`` the live state the
    deletion is subtracted from.  Retraction is pure subtraction on the
    carry's group fields, so the deletion batch may be chunked and
    ordered arbitrarily — including **sharded**: with ``num_streams > 1``
    the batch flows through
    :func:`~repro.streaming.parallel.run_parallel` exactly like an
    insertion batch (any backend: threads / vmap / shard_map), via the
    :class:`~repro.streaming.carry.RetractCarry` adapter; the group
    algebra makes the result bit-identical to the sequential drive.
    Returns the retracted carry (not finalized — retraction composes
    with further folds)."""
    if num_streams > 1 or backend is not None:
        from .carry import RetractCarry
        from .parallel import run_parallel

        adapter = RetractCarry(pc, with_parts=parts is not None)
        first = () if parts is None else (parts,)
        _, carry = run_parallel(stream, adapter, *first, *extras,
                                num_streams=num_streams,
                                super_chunk=super_chunk, shard=shard,
                                backend=backend, mesh=mesh, carry=carry)
        return carry
    if parts is None:
        for ch in stream.chunks(*extras):
            carry = pc.retract_chunk(carry, ch.src, ch.dst,
                                     jnp.int32(ch.n_valid), None, *ch.extras)
        return carry
    for ch in stream.chunks(parts, *extras):
        carry = pc.retract_chunk(carry, ch.src, ch.dst,
                                 jnp.int32(ch.n_valid), ch.extras[0],
                                 *ch.extras[1:])
    return carry


def run_scan(
    stream: EdgeStream,
    carry,
    chunk_fn: Callable,
    *extras,
):
    """Legacy driver: ``chunk_fn(carry, src, dst, *extras)`` over every
    chunk; returns (parts, final_carry).  ``parts`` is in arrival order."""
    return run_carry(stream, FnCarry(carry, chunk_fn), *extras)


def run_scan_batched(
    stream: EdgeStream,
    carries,
    chunk_fn: Callable,
    *extras,
):
    """Batched ``run_scan``: ``carries`` is a pytree with a leading batch
    axis (one entry per scenario — seed, λ, padded-k mask, …).  The chunk
    function is vmapped over the carry only; the stream is read once and
    broadcast.  Returns (parts (B, E), final carries)."""
    n_extra = len(extras)
    vfn = jax.vmap(chunk_fn, in_axes=(0, None, None) + (None,) * n_extra)
    outs = []
    for ch in stream.chunks(*extras):
        carries, parts = vfn(carries, ch.src, ch.dst, *ch.extras)
        outs.append(parts[..., : ch.n_valid])
    parts = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)
    return stream.scatter_back(parts), carries
