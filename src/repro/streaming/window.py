"""Sliding-window streams: track the last W edges of a churning graph.

``SlidingWindowStream`` turns any arrival-ordered :class:`EdgeStream`
(in-memory or the mmap-paged out-of-core ``ShardedEdgeStream``) into a
sequence of paired **insert / expire** events: each step admits the next
``step_edges`` arrivals and expires every edge that has fallen out of the
trailing ``window_edges``-wide window.  A decremental partitioner (the
group-structured carries of ``repro.streaming.carry`` + the deletion
machinery of ``repro.incremental``) folds the insert batch and retracts
the expire batch, so it continuously maintains a partition of exactly the
live window — the bounded-recency workload of window-based streaming
partitioning (Patwary et al. 2019) extended from *reordering* inside a
window to *membership* of the window.

Events carry arrival **indices** for the expired edges, because every
per-edge record the consumers keep (parts, cluster tags) is indexed by
arrival position; the edges themselves ride along so retraction never
needs random access back into the stream.

Memory: one step's insert + expire batches are materialized at a time —
O(step + expired) host bytes, never O(E); out-of-core streams page both
ranges straight from their shards.
"""

from __future__ import annotations

from typing import Iterator, NamedTuple

import numpy as np

__all__ = ["SlidingWindowStream", "WindowEvent"]


class WindowEvent(NamedTuple):
    """One churn step: admit ``[start, start+len(src))``, expire the rest."""

    src: np.ndarray  # (B,) int32 inserted edges, arrival order
    dst: np.ndarray  # (B,) int32
    start: int  # arrival index of the first inserted edge
    expire_src: np.ndarray  # (D,) int32 edges leaving the window
    expire_dst: np.ndarray  # (D,) int32
    expire_idx: np.ndarray  # (D,) int64 their arrival indices
    lo: int  # live window after this step is [lo, hi)
    hi: int

    @property
    def window_edges(self) -> int:
        return self.hi - self.lo


class SlidingWindowStream:
    """Pair insert/expire batches over a trailing window of ``window_edges``.

    ``step_edges`` (default: the base stream's chunk size, capped at the
    window) is the churn granularity — how many arrivals each event
    admits.  Expiry is strictly FIFO: the event's ``expire_idx`` is the
    contiguous arrival range ``[old_lo, new_lo)``, so after every event
    the live set is exactly the last ``window_edges`` arrivals (fewer
    while the window is still filling).
    """

    def __init__(self, stream, window_edges: int, *,
                 step_edges: int | None = None):
        if getattr(stream, "ordering", "natural") != "natural":
            raise ValueError(
                "sliding windows are defined over arrival order; got a "
                f"{stream.ordering!r}-ordered stream (window membership "
                "under a global reordering has no stable FIFO expiry)")
        if window_edges < 1:
            raise ValueError("window_edges must be >= 1")
        if step_edges is None:
            step_edges = min(int(stream.chunk_size), int(window_edges))
        if step_edges < 1:
            raise ValueError("step_edges must be >= 1")
        self.stream = stream
        self.window_edges = int(window_edges)
        self.step_edges = int(step_edges)

    @property
    def n_edges(self) -> int:
        return self.stream.n_edges

    @property
    def n_steps(self) -> int:
        return -(-self.n_edges // self.step_edges)

    def _range(self, a: int, b: int) -> tuple[np.ndarray, np.ndarray]:
        """Edges at arrival positions [a, b) — pages from disk for OOC."""
        if a >= b:
            z = np.zeros(0, np.int32)
            return z, z
        s, d = self.stream._edges_at(slice(a, b), a, b)
        # copy: out-of-core streams recycle their staging buffers per read
        return np.array(s, np.int32), np.array(d, np.int32)

    def events(self) -> Iterator[WindowEvent]:
        """A fresh replay of the full churn schedule (deterministic)."""
        E, W, B = self.n_edges, self.window_edges, self.step_edges
        lo = hi = 0
        while hi < E:
            new_hi = min(hi + B, E)
            new_lo = max(new_hi - W, 0)
            ins_s, ins_d = self._range(hi, new_hi)
            exp_s, exp_d = self._range(lo, new_lo)
            yield WindowEvent(
                src=ins_s, dst=ins_d, start=hi,
                expire_src=exp_s, expire_dst=exp_d,
                expire_idx=np.arange(lo, new_lo, dtype=np.int64),
                lo=new_lo, hi=new_hi,
            )
            lo, hi = new_lo, new_hi
