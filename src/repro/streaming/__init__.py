"""Unified edge-stream engine — the repo's single streaming contract.

Paper Fig. 2 runs one edge stream through three passes::

    edge stream ──▶ Alg. 1 clustering ──▶ Alg. 2 Stackelberg game
                └─▶ Θ statistics pass  └─▶ Alg. 3 edge placement

The seed code had four independent chunking loops (the scan baselines in
``core.baselines``, ``core.clustering.cluster_stream``, the Θ pass in
``core.s5p.cluster_statistics``, and ``core.postprocess.assign_edges_stream``).
This package is the one abstraction they all consume now:

- :class:`EdgeStream` — a chunked, multi-pass-replayable view over an edge
  list with a bounded device footprint (one chunk at a time) and pluggable
  arrival orderings (natural / shuffled / dst-sorted / windowed-buffer, the
  latter after Patwary et al. 2019's window streaming);
- :func:`run_scan` / :func:`run_scan_batched` — drivers that thread an
  O(k|V|) carry through per-chunk scan steps (compiled once, replayed per
  chunk; the batched form vmaps one compiled engine over many scenarios:
  seeds, HDRF λ values, or padded-k partition counts).

The hot per-chunk scan step (replica-bitmap lookup + score + load update)
has a fused Pallas kernel in ``repro.kernels.stream_scan`` with the
``lax.scan`` path as its oracle.

Mapping to the paper: *chunks* realize the bounded-memory stream of §2.1
(only O(|V| + k + chunk) state is live); *replay* gives the multi-pass
structure of Fig. 2 (clustering pass, Θ pass, placement pass are three
replays of one stream); *orderings* model arrival-order robustness (§6.5
studies stream order sensitivity).

Carry protocol + parallel ingest + deletions
--------------------------------------------
``carry`` defines :class:`PartitionerCarry` — ``init / step_chunk /
retract_chunk / merge / finalize`` with per-field merge semantics that
form an abelian **group** since the decremental refactor (replica tables
are COUNTED occupancy counters that OR-project for scoring, loads /
volumes / degree estimates / Θ sheets / assignment-table transitions
SUM) — and every streaming consumer in the repo (the greedy/HDRF/grid
scoring scans, Alg. 1 clustering, the Θ pass, Alg. 3 placement, the
degree precompute) is an implementation of it.  ``parallel`` shards one
logical stream into S sub-streams (:class:`ParallelEdgeStream`) and
drives any carry over them (:func:`run_parallel`) with carry all-reduces
at super-chunk boundaries — single-device vmapped lanes or one lane per
device under ``shard_map``; ``num_streams=1`` is bit-identical to the
sequential driver by construction.  The group structure is what makes
**edge deletion** exact: :func:`run_retract` drives ``retract_chunk``
over a deletion batch, subtracting precisely the accounting those edges'
insertion added (given their recorded per-edge parts), and
:class:`SlidingWindowStream` (``window``) turns any arrival-ordered
stream into paired insert/expire events so a partitioner tracks the last
W edges continuously.

Out-of-core (graphs ≫ RAM)
--------------------------
``oocstream`` extends the contract to disk: :func:`write_shards` lays an
edge list out as fixed-record ``.npy`` shards plus a small
``manifest.json`` (counts, dtypes, shard table), and
:class:`ShardedEdgeStream` memory-maps those shards and pages only the
chunks it needs — same ``chunks()`` / ``chunk_at()`` / ``scatter_back()``
surface, bit-identical chunks, so every consumer above runs unchanged on
graphs that never fit in host memory.  Memory knobs: ``shard_edges``
(write-time shard granularity = reorder-buffer bound), ``chunk_size``
(device-resident slice), ``window`` (windowed-ordering buffer), and
``scratch_dir`` (where the shuffled / dst-sorted external reorder passes
spill); ``stream.budget`` (a :class:`HostBudget`) accounts every host
allocation the stream makes, and the tests assert its peak stays
O(shard_edges + chunk + window).  CLI: ``python -m repro.launch.partition
--graph file:<manifest.json>`` partitions straight from shards, and
``--write-shards DIR`` converts any synthetic graph spec to shards.
"""

from .stream import Chunk, EdgeStream  # noqa: F401
from .carry import (  # noqa: F401
    CARRY_REPR,
    COUNTED,
    MAX,
    OR,
    REPLICATED,
    SUM,
    FnCarry,
    PartitionerCarry,
    RetractCarry,
)
from .engine import (  # noqa: F401
    as_stream,
    run_carry,
    run_retract,
    run_scan,
    run_scan_batched,
)
from .parallel import (  # noqa: F401
    IngestStats,
    LaneStats,
    ParallelEdgeStream,
    last_ingest_stats,
    reset_cadence_log,
    run_parallel,
)
from .oocstream import (  # noqa: F401
    BudgetExceededError,
    HostBudget,
    ShardedEdgeStream,
    append_shards,
    read_manifest,
    write_shards,
)
from .window import SlidingWindowStream, WindowEvent  # noqa: F401

__all__ = ["Chunk", "EdgeStream", "as_stream", "run_carry", "run_retract",
           "run_scan", "run_scan_batched", "PartitionerCarry", "FnCarry",
           "RetractCarry",
           "SUM", "COUNTED", "OR", "MAX", "REPLICATED", "CARRY_REPR",
           "ParallelEdgeStream", "run_parallel", "IngestStats", "LaneStats",
           "last_ingest_stats", "reset_cadence_log", "HostBudget",
           "BudgetExceededError",
           "ShardedEdgeStream", "read_manifest", "write_shards",
           "append_shards", "SlidingWindowStream", "WindowEvent"]
