"""Parallel ingest: one logical EdgeStream sharded into S sub-streams.

The HEP/CuSP-style regime (ROADMAP "Distributed streams"): S workers each
ingest a disjoint share of the stream's chunks, folding their own
:class:`~repro.streaming.carry.PartitionerCarry` replica, and the carries
are reconciled by the protocol's declared merge semantics (replica bitmaps
OR, loads/volumes/degree estimates/Θ tables SUM, assignment tables MAX)
once per *super-chunk* — the only cross-worker communication, O(|V|·k)
state per merge, never edges.

:class:`ParallelEdgeStream` is the sharding plan: it slices any
:class:`~repro.streaming.stream.EdgeStream` (in-memory or the mmap-paged
``ShardedEdgeStream``) into S logical sub-streams by contiguous chunk
**range** or chunk **round-robin**, and serves lockstep *rounds* — the
r-th chunk of every sub-stream, stacked into (S, B) device arrays (lanes
that ran out of chunks serve all-padding (0, 0) self-loop chunks, the
masked no-op every consumer already skips).

:func:`run_parallel` drives a carry over that plan with three backends
that produce **bit-identical results** (merges are integer/bool exact, so
reduction order cannot matter):

- ``"threads"``   — S host workers, each folding its sub-stream through
  the shared compiled chunk step (jax releases the GIL during execution,
  so workers genuinely overlap on multicore hosts; wall-clock gain is
  bounded by ``min(S, cores)``).  The default on single-device hosts.
- ``"shard_map"`` — one lane per device of a mesh axis (built over the
  first S local devices by default, or any provided mesh); the super-chunk
  merge becomes one ``psum``/``pmax`` collective per carry field — the
  same collective plumbing ``core.distributed`` uses.  The default when
  the platform reports ≥ S devices.  (Note: *forced* host-platform CPU
  devices execute serially — real parallelism needs real devices or the
  threads backend.)
- ``"vmap"``      — one compiled step processes all S lanes per round as
  a batch.  Semantically the reference backend; on XLA:CPU the batched
  per-edge scatters lower poorly, so use it for testing, not speed.

``num_streams=1`` (or a single-chunk stream) bypasses all of this and runs
the sequential :func:`~repro.streaming.engine.run_carry` driver — the
parallel path is additive, so every sequential result (and the pinned
golden hashes) is reproduced bit-identically by construction.
"""

from __future__ import annotations

import logging
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from .carry import PartitionerCarry
from .engine import run_carry
from .stream import EdgeStream

try:  # jax ≥ 0.5 top-level API; older releases ship it under experimental
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version shim
    from jax.experimental.shard_map import shard_map as _shard_map

# pvary (varying-axis annotation) only exists on newer jax; on older
# shard_map it is unnecessary — replicated operands are implicitly varying
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

__all__ = ["ParallelEdgeStream", "run_parallel"]

log = logging.getLogger(__name__)

SHARD_MODES = ("range", "round-robin")
LANE_FAILURE_MODES = ("raise", "replay")


class ParallelEdgeStream:
    """Shard a stream's chunk index space into S logical sub-streams.

    ``shard="range"`` gives sub-stream s the contiguous chunk range
    ``[s·⌈C/S⌉, (s+1)·⌈C/S⌉)`` (each worker scans a contiguous slice of
    the stream — the HEP file-split layout); ``shard="round-robin"`` deals
    chunk i to sub-stream ``i mod S`` (arrival-interleaved, the Le Merrer
    et al. multi-worker placement layout).  Either way every chunk belongs
    to exactly one sub-stream and sub-stream-local order preserves stream
    order.
    """

    def __init__(self, stream: EdgeStream, num_streams: int, *,
                 shard: str = "range"):
        if num_streams < 1:
            raise ValueError("num_streams must be >= 1")
        if shard not in SHARD_MODES:
            raise ValueError(f"unknown shard mode {shard!r}; one of {SHARD_MODES}")
        self.stream = stream
        self.shard = shard
        # more lanes than chunks would only add all-padding lanes
        self.num_streams = max(1, min(int(num_streams), stream.n_chunks))
        C, S = stream.n_chunks, self.num_streams
        if shard == "range":
            q = -(-C // S)
            self.lanes = [list(range(s * q, min((s + 1) * q, C)))
                          for s in range(S)]
        else:
            self.lanes = [list(range(s, C, S)) for s in range(S)]

    @property
    def n_rounds(self) -> int:
        """Lockstep rounds = chunks of the longest sub-stream."""
        return max(len(lane) for lane in self.lanes)

    def chunk_n_valid(self, chunk_id: int) -> int:
        cs, E = self.stream.chunk_size, self.stream.n_edges
        return min((chunk_id + 1) * cs, E) - chunk_id * cs

    def round_at(self, r: int, *extras):
        """Round r as stacked (S, B) arrays.

        Returns ``(src, dst, n_valid (S,), extras, chunk_ids)`` where
        ``chunk_ids[s]`` is the stream chunk served to lane s this round
        (``None`` for exhausted lanes, which get all-padding chunks).
        """
        st = self.stream
        B = st.chunk_size
        srcs, dsts, nvs, ids = [], [], [], []
        exs: list[list] = [[] for _ in extras]
        zero = None
        for lane in self.lanes:
            if r < len(lane):
                cid = lane[r]
                ch = st.chunk_at(cid, *extras)
                if ch.src.shape[0] != B:  # single-chunk streams never get here
                    raise AssertionError("parallel rounds need fixed-size chunks")
                srcs.append(ch.src)
                dsts.append(ch.dst)
                nvs.append(ch.n_valid)
                for j, e in enumerate(ch.extras):
                    exs[j].append(e)
                ids.append(cid)
            else:  # exhausted lane: all-padding (0, 0) self-loop chunk
                if zero is None:
                    zero = jnp.zeros((B,), jnp.int32)
                srcs.append(zero)
                dsts.append(zero)
                nvs.append(0)
                for j, e in enumerate(exs):
                    proto = e[0] if e else None
                    if proto is None:
                        raise AssertionError("padding lane before any real lane")
                    e.append(jnp.zeros_like(proto))
                ids.append(None)
        return (
            jnp.stack(srcs),
            jnp.stack(dsts),
            jnp.asarray(np.array(nvs, np.int32)),
            tuple(jnp.stack(e) for e in exs),
            ids,
        )


def _mask_inactive_step(pc):
    """Wrap ``pc.step_chunk`` so an all-padding chunk (``n_valid == 0`` —
    only exhausted lanes serve these) is a true carry no-op.  Consumers
    only guarantee no-op behaviour for *embedded* (0, 0) self-loops where
    it matters for results (HDRF, e.g., counts partial degrees for them,
    exactly as the sequential tail-padding does); an exhausted lane must
    contribute an exact identity delta instead."""

    def step(carry, src, dst, n_valid, *extras):
        new, parts = pc.step_chunk(carry, src, dst, n_valid, *extras)
        active = n_valid > 0
        kept = jax.tree_util.tree_map(
            lambda o, n: jnp.where(active, n, o), carry, new)
        return kept, parts

    return step


def _resolve_backend(backend, S):
    if backend is not None:
        return backend
    return "shard_map" if len(jax.devices()) >= S else "threads"


def _streams_mesh(S):
    return jax.sharding.Mesh(np.asarray(jax.devices()[:S]), ("streams",))


def run_parallel(
    stream: EdgeStream,
    pc: PartitionerCarry,
    *extras,
    num_streams: int = 1,
    super_chunk: int = 8,
    shard: str = "range",
    backend: str | None = None,
    mesh=None,
    carry=None,
    on_lane_failure: str = "raise",
    lane_injector=None,
    straggler=None,
    carry_store=None,
    carry_consumer: str | None = None,
    carry_config=None,
):
    """Drive ``pc`` over ``stream`` with S-way parallel ingest.

    Same return contract as :func:`~repro.streaming.engine.run_carry`:
    ``(parts_in_arrival_order | None, pc.finalize(final_carry))``.
    ``super_chunk`` is the number of rounds (chunks per sub-stream)
    between carry merges — smaller means fresher cross-worker state,
    larger means less communication.  ``num_streams=1`` delegates to the
    sequential driver and is bit-identical to it.  ``carry`` seeds the
    drive from a restored carry instead of ``pc.init()`` (the warm-start
    replay of ``repro.incremental``) — it becomes the first merge base,
    so SUM fields never double-count the restored state.

    Fault/straggler hardening (threads backend):

    - ``on_lane_failure="replay"`` — a lane whose fold raises mid-super-
      chunk is detected at the merge barrier and its chunk range replayed
      into a surviving worker, from the last committed merge base: lanes
      only ever publish state *at* merge points, so the replay is
      **bit-identical** to the unkilled drive.  With a ``carry_store``
      (:class:`~repro.incremental.store.CarryStore`) the merge bases are
      additionally checkpointed and the replay restores from disk — the
      recovery path a real worker death (not just a raised exception)
      needs.  ``"raise"`` (default) propagates the failure.
    - ``lane_injector`` — duck-typed ``check(lane, chunk_id)`` called
      before each chunk fold
      (:class:`~repro.runtime.fault.LaneFaultInjector`).
    - ``straggler`` — a :class:`~repro.runtime.straggler.StragglerMonitor`:
      per-lane super-chunk times feed its EMAs, and its
      ``rebalance_plan`` drives **live lane-range handoff** — a tail cut
      of each straggler lane's remaining chunks moves to the fastest
      lane at the next merge boundary.  Handoff regroups chunks between
      merge points — equivalent to having dealt a different (equally
      valid) lane assignment up front, so results drift within the same
      staleness envelope as changing ``num_streams``; quality bounds
      survive (the merge algebra is exact), bit-reproducibility of the
      no-handoff drive does not.
    """
    if num_streams < 1:
        raise ValueError("num_streams must be >= 1")
    if super_chunk < 1:
        raise ValueError("super_chunk must be >= 1")
    if on_lane_failure not in LANE_FAILURE_MODES:
        raise ValueError(f"unknown on_lane_failure {on_lane_failure!r}; "
                         f"one of {LANE_FAILURE_MODES}")
    if num_streams == 1 or stream.n_chunks <= 1:
        return run_carry(stream, pc, *extras, carry=carry)

    ps = ParallelEdgeStream(stream, num_streams, shard=shard)
    S = ps.num_streams
    backend = _resolve_backend(backend, S)
    wants_fault_path = (lane_injector is not None or straggler is not None
                        or carry_store is not None
                        or on_lane_failure != "raise")
    if wants_fault_path and backend != "threads":
        raise ValueError(
            "lane fault handling / straggler handoff / carry checkpoints "
            f"run on the threads backend (host workers die independently); "
            f"got backend={backend!r}")
    base = pc.init() if carry is None else carry
    parts_by_chunk: dict[int, jax.Array] = {}

    if backend == "vmap":
        n_ex = len(extras)
        # jit the vmapped step once per drive: rounds reuse one executable
        vstep = jax.jit(jax.vmap(_mask_inactive_step(pc),
                                 in_axes=(0, 0, 0, 0) + (0,) * n_ex))
        for r0 in range(0, ps.n_rounds, super_chunk):
            local = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    jnp.asarray(x), (S,) + jnp.shape(jnp.asarray(x))), base)
            for r in range(r0, min(r0 + super_chunk, ps.n_rounds)):
                src, dst, nv, exs, ids = ps.round_at(r, *extras)
                local, parts = vstep(local, src, dst, nv, *exs)
                if parts is not None:
                    for s, cid in enumerate(ids):
                        if cid is not None:
                            parts_by_chunk[cid] = parts[s]
            base = pc.merge_stacked(local, base)
    elif backend == "shard_map":
        mesh = mesh if mesh is not None else _streams_mesh(S)
        axis = mesh.axis_names[0]
        if mesh.shape[axis] != S:
            raise ValueError(
                f"shard_map backend needs a {S}-wide mesh axis, got "
                f"{mesh.shape[axis]} (use backend='threads' or 'vmap' on "
                f"hosts with fewer devices)")
        fns: dict[int, object] = {}  # jitted super-step per round count
        for r0 in range(0, ps.n_rounds, super_chunk):
            rounds = list(range(r0, min(r0 + super_chunk, ps.n_rounds)))
            blocks = [ps.round_at(r, *extras) for r in rounds]
            # (S, R, B) lane-major blocks for this super-chunk
            src_b = jnp.stack([b[0] for b in blocks], axis=1)
            dst_b = jnp.stack([b[1] for b in blocks], axis=1)
            nv_b = jnp.stack([b[2] for b in blocks], axis=1)
            exs_b = tuple(
                jnp.stack([b[3][j] for b in blocks], axis=1)
                for j in range(len(extras)))
            R = len(rounds)
            if R not in fns:
                fns[R] = _make_super_step(pc, mesh, axis, R, base,
                                          len(extras))
            base, parts_b = fns[R](base, src_b, dst_b, nv_b, *exs_b)
            base = jax.tree_util.tree_map(lambda x: x[0], base)
            if pc.emits_parts:
                for ri, r in enumerate(rounds):
                    ids = blocks[ri][4]
                    for s, cid in enumerate(ids):
                        if cid is not None:
                            parts_by_chunk[cid] = parts_b[s, ri]
    elif backend == "threads":
        # S host workers fold their sub-streams concurrently through the
        # shared compiled step (execution releases the GIL); chunk staging
        # is serialized under one lock — the out-of-core stream's budget
        # accounting and staging buffers are not thread-safe, and staging
        # is a small fraction of a chunk's scan cost.
        stage_lock = threading.Lock()
        # lanes are mutable here: straggler handoff re-deals remaining
        # chunks between merge boundaries (the sharding plan's own lists
        # stay pristine)
        lanes = [list(lane) for lane in ps.lanes]
        pos = [0] * S  # per-lane cursor into its (possibly re-dealt) list
        edges_done = 0  # edges committed through merges (checkpoint key)
        consumer = (carry_consumer if carry_consumer is not None
                    else f"parallel:{type(pc).__name__}")
        store_cfg = dict(carry_config or {})
        store_cfg.setdefault("super_chunk", int(super_chunk))
        store_cfg.setdefault("shard", shard)

        def lane_fold(lane_id, chunks, start, inject):
            local = start
            t0 = time.perf_counter()
            for cid in chunks:
                if inject is not None:
                    inject.check(lane_id, cid)
                with stage_lock:
                    ch = stream.chunk_at(cid, *extras)
                local, parts = pc.step_chunk(
                    local, ch.src, ch.dst, jnp.int32(ch.n_valid), *ch.extras)
                if parts is not None:
                    parts_by_chunk[cid] = parts[: ch.n_valid]
            return local, time.perf_counter() - t0

        def save_base(carry_val):
            if carry_store is not None:
                carry_store.save(carry_val, consumer=consumer,
                                 config=store_cfg, stream_pos=edges_done)

        def restore_base():
            if carry_store is None:
                return base  # in-memory merge base == last commit point
            restored, _ = carry_store.load(like=base, consumer=consumer,
                                           config=store_cfg,
                                           max_stream_pos=edges_done)
            return restored

        save_base(base)  # a lane can die before the first merge commits
        sc_index = 0
        with ThreadPoolExecutor(max_workers=S) as ex:
            while any(pos[s] < len(lanes[s]) for s in range(S)):
                batches = [lanes[s][pos[s]:pos[s] + super_chunk]
                           for s in range(S)]
                futs = [ex.submit(lane_fold, s, batches[s], base,
                                  lane_injector) for s in range(S)]
                locals_: list = [None] * S
                times = [0.0] * S
                failed: list[int] = []
                for s, f in enumerate(futs):
                    try:
                        locals_[s], times[s] = f.result()
                    except Exception as e:  # noqa: BLE001 — lane death
                        if on_lane_failure != "replay":
                            raise
                        log.warning("ingest lane %d died mid-super-chunk "
                                    "(%s); replaying its range", s, e)
                        failed.append(s)
                for s in failed:
                    # replay the dead lane's chunk range from the last
                    # committed base into a surviving worker — the merge
                    # below can't tell the difference (bit-identical)
                    locals_[s], times[s] = ex.submit(
                        lane_fold, s, batches[s], restore_base(),
                        None).result()
                base = pc.merge(locals_, base=base)
                edges_done += sum(ps.chunk_n_valid(cid)
                                  for b in batches for cid in b)
                for s in range(S):
                    pos[s] += len(batches[s])
                save_base(base)
                if straggler is not None:
                    for s in range(S):
                        if batches[s]:
                            # per-chunk time: lane *speed*, not workload
                            straggler.record(sc_index,
                                             times[s] / len(batches[s]),
                                             shard=s)
                    _handoff_lanes(lanes, pos, straggler)
                sc_index += 1
    else:
        raise ValueError(f"unknown backend {backend!r}")

    result = pc.finalize(base)
    if not parts_by_chunk:
        return None, result
    outs = [parts_by_chunk[cid][: ps.chunk_n_valid(cid)]
            for cid in range(stream.n_chunks)]
    parts = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
    return stream.scatter_back(parts), result


def _handoff_lanes(lanes, pos, straggler):
    """Live lane-range handoff at a merge boundary: ask the monitor's
    :meth:`rebalance_plan` what tail cut each straggler lane should give
    up, and physically move those chunk ids to the receiving lane's
    queue.  Chunks already folded (before ``pos``) never move."""
    ranges = [(pos[s], len(lanes[s])) for s in range(len(lanes))]
    plan = straggler.rebalance_plan(ranges)
    if plan == ranges:
        return
    moved: list[int] = []
    receiver = None
    for s, ((_, hi_old), (_, hi_new)) in enumerate(zip(ranges, plan)):
        if hi_new < hi_old:
            cut = hi_old - hi_new
            moved.extend(lanes[s][len(lanes[s]) - cut:])
            del lanes[s][len(lanes[s]) - cut:]
        elif hi_new > hi_old:
            receiver = s
    if receiver is not None and moved:
        # keep stream order within the receiving lane's tail
        lanes[receiver].extend(sorted(moved))
        log.info("straggler handoff: %d chunk(s) moved to lane %d",
                 len(moved), receiver)


def _make_super_step(pc, mesh, axis, R, base, n_ex):
    """Build the jitted shard_map super-step for R rounds: each device
    folds its lane's R chunks from the replicated base carry, then the
    carries are merged by one collective per field.  Returns a callable
    ``(base, src (S,R,B), dst, nv (S,R), *extras) -> (merged (S-stacked,
    identical per lane — caller takes lane 0), parts (S, R, B))``."""
    P = jax.sharding.PartitionSpec
    lane = P(axis)

    step = _mask_inactive_step(pc)

    def body(base_carry, src, dst, nv, *exs):
        local = jax.tree_util.tree_map(
            lambda x: _pvary(x, (axis,)), base_carry)
        parts_rounds = []
        for r in range(R):
            local, parts = step(
                local, src[0, r], dst[0, r], nv[0, r],
                *[e[0, r] for e in exs])
            if pc.emits_parts:
                parts_rounds.append(parts)
        merged = pc.merge_collective(local, base_carry, axis)
        merged = jax.tree_util.tree_map(lambda x: x[None], merged)
        if parts_rounds:
            return merged, jnp.stack(parts_rounds)[None]
        return merged, jnp.zeros((1, 1, 1), jnp.int32)

    return jax.jit(_shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: P(), base),
                  lane, lane, lane) + (lane,) * n_ex,
        out_specs=(jax.tree_util.tree_map(lambda _: lane, base), lane),
    ))
