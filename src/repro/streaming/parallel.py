"""Parallel ingest: one logical EdgeStream sharded into S sub-streams.

The HEP/CuSP-style regime (ROADMAP "Distributed streams"): S workers each
ingest a disjoint share of the stream, folding their own
:class:`~repro.streaming.carry.PartitionerCarry` replica, and the carries
are reconciled by the protocol's declared merge semantics (replica bitmaps
OR, loads/volumes/degree estimates/Θ tables SUM, assignment tables MAX)
once per *super-chunk* — the only cross-worker communication, O(|V|·k)
state per merge, never edges.

:class:`ParallelEdgeStream` is the sharding plan: it slices any
:class:`~repro.streaming.stream.EdgeStream` (in-memory or the mmap-paged
``ShardedEdgeStream``) into S logical sub-streams and serves lockstep
*rounds* — the r-th chunk of every sub-stream, stacked into (S, B) device
arrays (lanes that ran out of chunks serve all-padding (0, 0) self-loop
chunks, the masked no-op every consumer already skips).  Three shard
modes:

- ``"range"``       — chunk-granular: lane s scans the contiguous chunk
  range ``[s·⌈C/S⌉, (s+1)·⌈C/S⌉)`` (the HEP file-split layout);
- ``"round-robin"`` — chunk-granular: chunk i goes to lane ``i mod S``
  (arrival-interleaved; ``"rr"`` is an accepted alias);
- ``"hub"``         — **edge-granular, hub-pinned**: an online CMS degree
  sketch (the same ``core.cms`` machinery the Θ pass and the hybrid
  budget planner use) classifies each edge's min-degree endpoint as
  hub/tail at plan time; every edge of a given hub routes to one pinned
  lane (rendezvous hash on the vertex id), so a hub's replica set is
  built by exactly one lane and **never diverges across lanes**, while
  tail edges keep round-robin load balance.  This is what makes S-way
  ingest quality-neutral on power-law graphs: carry staleness collapses
  to the (cheap, exactly-mergeable) tail.

``super_chunk`` may be a fixed chunk count or ``"auto"`` — an adaptive
cadence controller that merges after every chunk while placements are
contested (measured by the per-merge delta in replica-table occupancy —
see :meth:`~repro.streaming.carry.PartitionerCarry.occupancy_contest`)
and backs off geometrically as the tables warm; state-only carries
(clustering, the sketches) instead fold in full lane isolation and merge
once at the end (see :class:`_CadenceController`).  The chosen schedule is
logged once per run (``reset_cadence_log`` re-arms, mirroring the kernel
ladder's ``reset_path_log``) and exposed — with per-lane
``(chunks, edges, merge_count, wall_s)`` stats — via
:func:`last_ingest_stats`.

:func:`run_parallel` drives a carry over that plan with three backends
that produce **bit-identical results on the same plan** (merges are
integer/bool exact, so reduction order cannot matter):

- ``"threads"``   — S host workers, each folding its sub-stream through
  the shared compiled chunk step (jax releases the GIL during execution,
  so workers genuinely overlap on multicore hosts; wall-clock gain is
  bounded by ``min(S, cores)``).  The default on single-device hosts.
- ``"shard_map"`` — one lane per device of a mesh axis (built over the
  first S local devices by default, or any provided mesh); the super-chunk
  merge becomes one ``psum``/``pmax`` collective per carry field — the
  same collective plumbing ``core.distributed`` uses.  The default when
  the platform reports ≥ S devices.  (Note: *forced* host-platform CPU
  devices execute serially — real parallelism needs real devices or the
  threads backend.)
- ``"vmap"``      — one compiled step processes all S lanes per round as
  a batch.  Semantically the reference backend; on XLA:CPU the batched
  per-edge scatters lower poorly, so use it for testing, not speed.

``num_streams=1`` (or a single-chunk stream) bypasses all of this and runs
the sequential :func:`~repro.streaming.engine.run_carry` driver — the
parallel path is additive, so every sequential result (and the pinned
golden hashes) is reproduced bit-identically by construction, in every
shard mode.
"""

from __future__ import annotations

import dataclasses
import logging
import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from .carry import PartitionerCarry
from .engine import run_carry
from .stream import Chunk, EdgeStream

try:  # jax ≥ 0.5 top-level API; older releases ship it under experimental
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version shim
    from jax.experimental.shard_map import shard_map as _shard_map

# pvary (varying-axis annotation) only exists on newer jax; on older
# shard_map it is unnecessary — replicated operands are implicitly varying
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

__all__ = ["ParallelEdgeStream", "run_parallel", "IngestStats", "LaneStats",
           "last_ingest_stats", "reset_cadence_log"]

log = logging.getLogger(__name__)

SHARD_MODES = ("range", "round-robin", "hub")
_SHARD_ALIASES = {"rr": "round-robin"}
LANE_FAILURE_MODES = ("raise", "replay")

#: adaptive-cadence knobs: merge every chunk while the per-merge replica-
#: occupancy delta exceeds WARM (the contested regime), then back off
#: geometrically (1 → 2 → 4 → …) up to CAP chunks between merges
AUTO_CADENCE_WARM = 0.05
AUTO_CADENCE_CAP = 32

#: the "merge once at the end" cadence auto mode resolves to for
#: state-only carries (every backend clamps a super-chunk to the rounds
#: actually remaining, so any value ≥ the stream length means isolation)
ISOLATE_CADENCE = 1 << 30


# ---------------------------------------------------------------------------
# observability: per-lane ingest stats + once-per-run cadence-schedule log
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LaneStats:
    """One lane's share of a ``run_parallel`` drive."""

    chunks: int
    edges: int
    merge_count: int
    wall_s: float


@dataclasses.dataclass(frozen=True)
class IngestStats:
    """What one ``run_parallel`` drive actually did — consumed by the
    benches and the StragglerMonitor instead of re-deriving it.

    ``schedule`` is the realized merge cadence (chunks per lane between
    consecutive merges); for ``super_chunk="auto"`` it is the controller's
    trace, for a fixed cadence it repeats that value.  ``wall_s`` is
    per-lane fold time on the threads backend and the shared loop time on
    the vmap/shard_map backends (lanes there execute as one program).
    """

    num_streams: int
    shard: str
    backend: str
    super_chunk: int | str
    schedule: tuple[int, ...]
    lanes: tuple[LaneStats, ...]

    def as_dict(self) -> dict:
        return {
            "num_streams": self.num_streams,
            "shard": self.shard,
            "backend": self.backend,
            "super_chunk": self.super_chunk,
            "schedule": list(self.schedule),
            "lanes": [dataclasses.asdict(l) for l in self.lanes],
        }


_last_stats: IngestStats | None = None
_logged_schedules: set[tuple] = set()


def last_ingest_stats() -> IngestStats | None:
    """Stats of the most recent :func:`run_parallel` drive (any backend,
    including the sequential ``num_streams=1`` delegation)."""
    return _last_stats


def reset_cadence_log() -> None:
    """Re-arm the once-per-run cadence-schedule logging (used by tests —
    the same contract as the kernel ladder's ``reset_path_log``)."""
    _logged_schedules.clear()


def _compress_schedule(schedule) -> str:
    """``[1,1,1,2,4,8,8]`` → ``"1×3,2,4,8×2"`` for one-line logging
    (:data:`ISOLATE_CADENCE` renders as ``"all"``)."""
    out, i = [], 0
    schedule = ["all" if c == ISOLATE_CADENCE else c for c in schedule]
    while i < len(schedule):
        j = i
        while j < len(schedule) and schedule[j] == schedule[i]:
            j += 1
        out.append(str(schedule[i]) if j - i == 1 else f"{schedule[i]}×{j - i}")
        i = j
    return ",".join(out)


def _log_schedule(consumer: str, stats: IngestStats) -> None:
    key = (consumer, stats.shard, stats.super_chunk, stats.schedule)
    if key in _logged_schedules:
        return
    _logged_schedules.add(key)
    log.info("ingest %s: S=%d shard=%s super_chunk=%s → cadence [%s] "
             "(%d merges)", consumer, stats.num_streams, stats.shard,
             stats.super_chunk, _compress_schedule(stats.schedule),
             len(stats.schedule))


class _CadenceController:
    """Merge-cadence policy shared by all three backends.

    Fixed ``super_chunk`` replays that value.  ``"auto"`` is
    consumer-aware:

    - carries that **emit per-edge parts** (the placement scans: HDRF,
      greedy, grid, Alg. 3 assignment) start at 1 — merge after every
      chunk while placements are contested, because every un-merged chunk
      is edges placed against stale replica tables — and double whenever
      a merge's occupancy delta falls below :data:`AUTO_CADENCE_WARM`,
      re-arming to 1 when contest re-spikes (a burst of new vertices).
      The geometric ladder keeps the shard_map backend's per-round-count
      compile cache to O(log CAP) distinct entries.
    - **state-only** carries (``emits_parts=False``: Alg. 1 clustering,
      the degree/Θ sketches) resolve to :data:`ISOLATE_CADENCE` — lanes
      fold in full isolation and merge exactly once at the end.  No
      per-edge decision is emitted mid-stream, so mid-stream merges buy
      no placement consistency; what they *do* is couple the lanes'
      assignment tables (measured on the block R-MAT bench: isolated
      hub-sharded clustering lands *under* the sequential RF, while a
      1 → 2 → 4 ramp is the worst of both regimes).  Linear sketches are
      cadence-invariant, so isolation is also the cheapest exact choice.
    """

    def __init__(self, pc: PartitionerCarry, super_chunk: int | str):
        self.pc = pc
        self.auto = super_chunk == "auto"
        self.isolate = self.auto and not pc.emits_parts
        if self.isolate:
            self.cadence = ISOLATE_CADENCE
        else:
            self.cadence = 1 if self.auto else int(super_chunk)
        self.schedule: list[int] = []

    def next(self) -> int:
        self.schedule.append(self.cadence)
        return self.cadence

    def observe(self, prev_base, new_base) -> None:
        if not self.auto or self.isolate:
            return
        contest = self.pc.occupancy_contest(prev_base, new_base)
        if contest > AUTO_CADENCE_WARM:
            self.cadence = 1
        else:
            self.cadence = min(self.cadence * 2, AUTO_CADENCE_CAP)


# ---------------------------------------------------------------------------
# sharding plan
# ---------------------------------------------------------------------------


def _rendezvous_lanes(v: np.ndarray, S: int) -> np.ndarray:
    """Highest-random-weight (rendezvous) lane per vertex id.

    ``argmax_s h(v, s)`` over an avalanche mix — stable under lane-count
    changes in the HRW sense and, more importantly here, a pure function
    of the vertex id, so every edge of a hub lands on the same lane no
    matter which chunk it arrives in."""
    with np.errstate(over="ignore"):
        h = (v.astype(np.uint32)[:, None] * np.uint32(0x9E3779B1)) ^ (
            np.arange(S, dtype=np.uint32)[None, :] * np.uint32(0x85EBCA6B))
        h ^= h >> np.uint32(16)
        h *= np.uint32(0x85EBCA6B)
        h ^= h >> np.uint32(13)
        h *= np.uint32(0xC2B2AE35)
        h ^= h >> np.uint32(16)
    return np.argmax(h, axis=1).astype(np.int32)


class ParallelEdgeStream:
    """Shard a stream into S logical sub-streams (see module docstring).

    ``"range"``/``"round-robin"`` shard the chunk index space; ``"hub"``
    shards the *edge* space: a plan-time pass classifies each edge by its
    min-endpoint's online CMS degree estimate (hub iff estimate >
    ``hub_threshold``, default the stream's average degree), pins hub
    edges to ``rendezvous(vertex)`` lanes and deals tail edges round-robin,
    then packs each lane's edges (stream order preserved) into synthetic
    fixed-size chunks.  Either way every edge belongs to exactly one
    sub-stream and sub-stream-local order preserves stream order.
    """

    def __init__(self, stream: EdgeStream, num_streams: int, *,
                 shard: str = "range", hub_threshold: int | None = None):
        shard = _SHARD_ALIASES.get(shard, shard)
        if num_streams < 1:
            raise ValueError("num_streams must be >= 1")
        if shard not in SHARD_MODES:
            raise ValueError(f"unknown shard mode {shard!r}; one of {SHARD_MODES}")
        self.stream = stream
        self.shard = shard
        # more lanes than chunks would only add all-padding lanes
        self.num_streams = max(1, min(int(num_streams), stream.n_chunks))
        C, S = stream.n_chunks, self.num_streams
        self._chunk_pos: list[np.ndarray] | None = None  # hub registry
        self._lane_of_pos: np.ndarray | None = None
        self._pin_vertex: np.ndarray | None = None
        self.pin_map: dict[int, int] = {}
        self.hub_threshold: int | None = None
        if shard == "range":
            q = -(-C // S)
            self.lanes = [list(range(s * q, min((s + 1) * q, C)))
                          for s in range(S)]
        elif shard == "round-robin":
            self.lanes = [list(range(s, C, S)) for s in range(S)]
        else:
            self._build_hub_plan(hub_threshold)

    # ---------------------------------------------------------- hub plan
    def _build_hub_plan(self, hub_threshold: int | None) -> None:
        # lazy import: core.cms imports streaming.carry, so a module-level
        # import here would cycle through the package __init__s
        from ..core.cms import cms_query, cms_update, make_sketch, \
            suggest_params, vertex_key

        st, S = self.stream, self.num_streams
        if (type(st)._edges_at is not EdgeStream._edges_at
                and st.order is not None):
            raise ValueError(
                "shard='hub' needs per-edge gathers; reordered out-of-core "
                "streams serve edges by stream-order ranges only — use "
                "ordering='natural' or an in-memory stream")
        E, V, B = st.n_edges, st.n_vertices, st.chunk_size
        if hub_threshold is None:
            # the ξ-style default: a vertex is a hub past the average degree
            hub_threshold = max(2, int(2.0 * E / max(V, 1)))
        self.hub_threshold = int(hub_threshold)
        w, d = suggest_params()
        width = w * max(1, int(math.sqrt(max(V, 1))))
        sketch = make_sketch(width, d, seed=st.seed)
        lane_of_pos = np.empty(E, np.int32)
        pin_vertex = np.full(E, -1, np.int32)
        tail_lane = np.full(V, -1, np.int32)  # tail vertex → dealt lane
        rr = 0  # round-robin cursor for newly seen tail vertices
        for i in range(st.n_chunks):
            ch = st.chunk_at(i)
            nv = ch.n_valid
            s = np.asarray(ch.src)[:nv]
            t = np.asarray(ch.dst)[:nv]
            # query *before* update: the estimate is online (edges seen in
            # prior chunks only), the HDRF-style partial-degree regime —
            # early edges of a not-yet-recognized hub go tail-routed, which
            # is exactly the HDRF partial-degree tradeoff and costs only
            # the warm-up prefix
            est_s = np.asarray(cms_query(sketch, vertex_key(jnp.asarray(s))))
            est_t = np.asarray(cms_query(sketch, vertex_key(jnp.asarray(t))))
            # the hub endpoint is the *higher-degree* one (ties break to
            # the smaller id, deterministically): its replica set is the
            # expensive one to let go stale, so its edges are what we pin
            s_wins = (est_s > est_t) | ((est_s == est_t) & (s <= t))
            hub_v = np.where(s_wins, s, t)
            is_hub = (np.maximum(est_s, est_t) > self.hub_threshold) & (s != t)
            lanes_c = np.empty(nv, np.int32)
            hub_idx = np.flatnonzero(is_hub)
            if hub_idx.size:
                lanes_c[hub_idx] = _rendezvous_lanes(hub_v[hub_idx], S)
            # tail edges route by their *lower-degree* endpoint (the DBH
            # rule: that's the vertex whose replica set must not scatter),
            # and the routing is vertex-granular round-robin — each newly
            # seen tail vertex is dealt the next lane cyclically, so lane
            # loads stay balanced while every tail vertex's edges stay on
            # one lane (per-edge round-robin would hand a degree-d vertex
            # ~d replicas purely from lane divergence)
            tail_idx = np.flatnonzero(~is_hub)
            if tail_idx.size:
                tv = np.where(s_wins, t, s)[tail_idx]
                newv = tv[tail_lane[tv] < 0]
                if newv.size:
                    _, first = np.unique(newv, return_index=True)
                    order_v = newv[np.sort(first)]  # first-appearance order
                    tail_lane[order_v] = (rr + np.arange(order_v.size)) % S
                    rr = (rr + order_v.size) % S
                lanes_c[tail_idx] = tail_lane[tv]
            pos0 = i * B
            lane_of_pos[pos0:pos0 + nv] = lanes_c
            pin_vertex[pos0 + hub_idx] = hub_v[hub_idx]
            counts = jnp.asarray((s != t).astype(np.uint32))
            sketch = cms_update(sketch, vertex_key(jnp.asarray(s)), counts)
            sketch = cms_update(sketch, vertex_key(jnp.asarray(t)), counts)
        self._lane_of_pos = lane_of_pos
        self._pin_vertex = pin_vertex
        for v in np.unique(pin_vertex[pin_vertex >= 0]):
            first = np.flatnonzero(pin_vertex == v)[0]
            self.pin_map[int(v)] = int(lane_of_pos[first])
        self._chunk_pos = []
        self.lanes = []
        for s in range(S):
            pos_s = np.flatnonzero(lane_of_pos == s).astype(np.int64)
            self.lanes.append(self._register_chunks(pos_s))

    def _register_chunks(self, positions: np.ndarray) -> list[int]:
        """Pack stream positions (ascending = stream order) into synthetic
        fixed-size chunks; returns the new chunk ids."""
        B = self.stream.chunk_size
        cids = []
        for i in range(0, len(positions), B):
            cids.append(len(self._chunk_pos))
            self._chunk_pos.append(positions[i:i + B])
        return cids

    @property
    def n_hubs(self) -> int:
        return len(self.pin_map)

    def edge_lanes(self) -> np.ndarray:
        """Per-edge lane id in **arrival order** — the provenance map the
        post-ingest touch-up uses to find clusters written by ≥ 2 lanes."""
        st = self.stream
        if self.shard == "hub":
            by_pos = self._lane_of_pos
        else:
            B = st.chunk_size
            lane_of_chunk = np.empty(st.n_chunks, np.int32)
            for s, lane in enumerate(self.lanes):
                lane_of_chunk[np.asarray(lane, np.int64)] = s
            by_pos = lane_of_chunk[
                np.minimum(np.arange(st.n_edges) // B, st.n_chunks - 1)]
        if st.order is None:
            return by_pos.astype(np.int32)
        out = np.empty(st.n_edges, np.int32)
        out[np.asarray(st.order)] = by_pos
        return out

    # ------------------------------------------------------------ serving
    @property
    def n_rounds(self) -> int:
        """Lockstep rounds = chunks of the longest sub-stream."""
        return max(len(lane) for lane in self.lanes)

    def chunk_n_valid(self, chunk_id: int) -> int:
        if self._chunk_pos is not None:
            return len(self._chunk_pos[chunk_id])
        cs, E = self.stream.chunk_size, self.stream.n_edges
        return min((chunk_id + 1) * cs, E) - chunk_id * cs

    def chunk_for(self, chunk_id: int, *extras) -> Chunk:
        """The chunk behind a plan chunk id: the stream's own chunk in the
        chunk-granular modes, a gathered synthetic chunk in hub mode."""
        if self._chunk_pos is None:
            return self.stream.chunk_at(chunk_id, *extras)
        st = self.stream
        pos = self._chunk_pos[chunk_id]
        B = st.chunk_size
        arr = pos if st.order is None else np.asarray(st.order)[pos]
        ex = [e if hasattr(e, "shape") else np.asarray(e) for e in extras]
        s, d = st._edges_at(np.asarray(arr), 0, len(pos))
        s = np.asarray(s, np.int32)
        d = np.asarray(d, np.int32)
        exc = [np.asarray(e)[arr] for e in ex]
        nv = len(pos)
        if nv < B:  # pad to the fixed chunk size ((0,0) self-loop no-ops)
            padn = B - nv
            s = np.concatenate([s, np.zeros(padn, np.int32)])
            d = np.concatenate([d, np.zeros(padn, np.int32)])
            exc = [np.concatenate(
                [e, np.zeros((padn,) + e.shape[1:], e.dtype)]) for e in exc]
        return Chunk(src=jnp.asarray(s), dst=jnp.asarray(d),
                     extras=tuple(jnp.asarray(e) for e in exc),
                     start=int(pos[0]) if nv else 0, n_valid=nv)

    def round_at(self, r: int, *extras):
        """Round r as stacked (S, B) arrays.

        Returns ``(src, dst, n_valid (S,), extras, chunk_ids)`` where
        ``chunk_ids[s]`` is the plan chunk served to lane s this round
        (``None`` for exhausted lanes, which get all-padding chunks).
        """
        B = self.stream.chunk_size
        srcs, dsts, nvs, ids = [], [], [], []
        exs: list[list] = [[] for _ in extras]
        zero = None
        for lane in self.lanes:
            if r < len(lane):
                cid = lane[r]
                ch = self.chunk_for(cid, *extras)
                if ch.src.shape[0] != B:  # single-chunk streams never get here
                    raise AssertionError("parallel rounds need fixed-size chunks")
                srcs.append(ch.src)
                dsts.append(ch.dst)
                nvs.append(ch.n_valid)
                for j, e in enumerate(ch.extras):
                    exs[j].append(e)
                ids.append(cid)
            else:  # exhausted lane: all-padding (0, 0) self-loop chunk
                if zero is None:
                    zero = jnp.zeros((B,), jnp.int32)
                srcs.append(zero)
                dsts.append(zero)
                nvs.append(0)
                for j, e in enumerate(exs):
                    proto = e[0] if e else None
                    if proto is None:
                        raise AssertionError("padding lane before any real lane")
                    e.append(jnp.zeros_like(proto))
                ids.append(None)
        return (
            jnp.stack(srcs),
            jnp.stack(dsts),
            jnp.asarray(np.array(nvs, np.int32)),
            tuple(jnp.stack(e) for e in exs),
            ids,
        )


def _mask_inactive_step(pc):
    """Wrap ``pc.step_chunk`` so an all-padding chunk (``n_valid == 0`` —
    only exhausted lanes serve these) is a true carry no-op.  Consumers
    only guarantee no-op behaviour for *embedded* (0, 0) self-loops where
    it matters for results (HDRF, e.g., counts partial degrees for them,
    exactly as the sequential tail-padding does); an exhausted lane must
    contribute an exact identity delta instead."""

    def step(carry, src, dst, n_valid, *extras):
        new, parts = pc.step_chunk(carry, src, dst, n_valid, *extras)
        active = n_valid > 0
        kept = jax.tree_util.tree_map(
            lambda o, n: jnp.where(active, n, o), carry, new)
        return kept, parts

    return step


def _resolve_backend(backend, S):
    if backend is not None:
        return backend
    return "shard_map" if len(jax.devices()) >= S else "threads"


def _streams_mesh(S):
    return jax.sharding.Mesh(np.asarray(jax.devices()[:S]), ("streams",))


def _publish_stats(pc, stats: IngestStats) -> IngestStats:
    global _last_stats
    _last_stats = stats
    _log_schedule(type(pc).__name__, stats)
    return stats


def run_parallel(
    stream: EdgeStream,
    pc: PartitionerCarry,
    *extras,
    num_streams: int = 1,
    super_chunk: int | str = 8,
    shard: str = "range",
    hub_threshold: int | None = None,
    backend: str | None = None,
    mesh=None,
    carry=None,
    on_lane_failure: str = "raise",
    lane_injector=None,
    straggler=None,
    carry_store=None,
    carry_consumer: str | None = None,
    carry_config=None,
):
    """Drive ``pc`` over ``stream`` with S-way parallel ingest.

    Same return contract as :func:`~repro.streaming.engine.run_carry`:
    ``(parts_in_arrival_order | None, pc.finalize(final_carry))``.
    ``super_chunk`` is the number of rounds (chunks per sub-stream)
    between carry merges — smaller means fresher cross-worker state,
    larger means less communication — or ``"auto"`` for the adaptive
    cadence controller (merge every chunk while contested, geometric
    backoff as the tables warm).  ``shard`` picks the lane layout
    (``"range"`` / ``"round-robin"`` / ``"hub"`` — see
    :class:`ParallelEdgeStream`); ``hub_threshold`` overrides hub mode's
    min-endpoint degree cut.  ``num_streams=1`` delegates to the
    sequential driver and is bit-identical to it in every mode.
    ``carry`` seeds the drive from a restored carry instead of
    ``pc.init()`` (the warm-start replay of ``repro.incremental``) — it
    becomes the first merge base, so SUM fields never double-count the
    restored state.  Per-lane stats and the realized cadence schedule
    are published through :func:`last_ingest_stats`.

    Fault/straggler hardening (threads backend):

    - ``on_lane_failure="replay"`` — a lane whose fold raises mid-super-
      chunk is detected at the merge barrier and its chunk range replayed
      into a surviving worker, from the last committed merge base: lanes
      only ever publish state *at* merge points, so the replay is
      **bit-identical** to the unkilled drive (the plan — including hub
      mode's synthetic chunk registry — is deterministic, so the replayed
      chunks are the same chunks).  With a ``carry_store``
      (:class:`~repro.incremental.store.CarryStore`) the merge bases are
      additionally checkpointed and the replay restores from disk — the
      recovery path a real worker death (not just a raised exception)
      needs.  ``"raise"`` (default) propagates the failure.
    - ``lane_injector`` — duck-typed ``check(lane, chunk_id)`` called
      before each chunk fold
      (:class:`~repro.runtime.fault.LaneFaultInjector`).
    - ``straggler`` — a :class:`~repro.runtime.straggler.StragglerMonitor`:
      per-lane super-chunk times feed its EMAs, and its
      ``rebalance_plan`` drives **live lane-range handoff** — a tail cut
      of each straggler lane's remaining chunks moves to the fastest
      lane at the next merge boundary.  In hub mode the handoff is
      hub-granular: a hub's remaining edges move **wholesale** and its
      ``pin_map`` entry moves with them, so pinning (one lane owns a hub
      at any time, per-hub stream order intact) survives the handoff.
      Handoff regroups chunks between merge points — equivalent to
      having dealt a different (equally valid) lane assignment up front,
      so results drift within the same staleness envelope as changing
      ``num_streams``; quality bounds survive (the merge algebra is
      exact), bit-reproducibility of the no-handoff drive does not.
    """
    if num_streams < 1:
        raise ValueError("num_streams must be >= 1")
    if isinstance(super_chunk, str):
        if super_chunk != "auto":
            raise ValueError(
                f"super_chunk must be >= 1 or 'auto', got {super_chunk!r}")
    elif super_chunk < 1:
        raise ValueError("super_chunk must be >= 1")
    shard = _SHARD_ALIASES.get(shard, shard)
    if shard not in SHARD_MODES:
        raise ValueError(f"unknown shard mode {shard!r}; one of {SHARD_MODES}")
    if on_lane_failure not in LANE_FAILURE_MODES:
        raise ValueError(f"unknown on_lane_failure {on_lane_failure!r}; "
                         f"one of {LANE_FAILURE_MODES}")
    if num_streams == 1 or stream.n_chunks <= 1:
        t0 = time.perf_counter()
        out = run_carry(stream, pc, *extras, carry=carry)
        _publish_stats(pc, IngestStats(
            num_streams=1, shard=shard, backend="sequential",
            super_chunk=super_chunk, schedule=(),
            lanes=(LaneStats(chunks=stream.n_chunks, edges=stream.n_edges,
                             merge_count=0,
                             wall_s=time.perf_counter() - t0),)))
        return out

    ps = ParallelEdgeStream(stream, num_streams, shard=shard,
                            hub_threshold=hub_threshold)
    S = ps.num_streams
    backend = _resolve_backend(backend, S)
    wants_fault_path = (lane_injector is not None or straggler is not None
                        or carry_store is not None
                        or on_lane_failure != "raise")
    if wants_fault_path and backend != "threads":
        raise ValueError(
            "lane fault handling / straggler handoff / carry checkpoints "
            f"run on the threads backend (host workers die independently); "
            f"got backend={backend!r}")
    base = pc.init() if carry is None else carry
    parts_by_chunk: dict[int, jax.Array] = {}
    ctl = _CadenceController(pc, super_chunk)
    t_run = time.perf_counter()
    lane_chunks = [0] * S
    lane_edges = [0] * S
    lane_wall = [0.0] * S

    if backend == "vmap":
        n_ex = len(extras)
        # jit the vmapped step once per drive: rounds reuse one executable
        vstep = jax.jit(jax.vmap(_mask_inactive_step(pc),
                                 in_axes=(0, 0, 0, 0) + (0,) * n_ex))
        r0 = 0
        while r0 < ps.n_rounds:
            sc = ctl.next()
            local = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    jnp.asarray(x), (S,) + jnp.shape(jnp.asarray(x))), base)
            for r in range(r0, min(r0 + sc, ps.n_rounds)):
                src, dst, nv, exs, ids = ps.round_at(r, *extras)
                local, parts = vstep(local, src, dst, nv, *exs)
                if parts is not None:
                    for s, cid in enumerate(ids):
                        if cid is not None:
                            parts_by_chunk[cid] = parts[s]
            prev = base
            base = pc.merge_stacked(local, prev)
            ctl.observe(prev, base)
            r0 += sc
    elif backend == "shard_map":
        mesh = mesh if mesh is not None else _streams_mesh(S)
        axis = mesh.axis_names[0]
        if mesh.shape[axis] != S:
            raise ValueError(
                f"shard_map backend needs a {S}-wide mesh axis, got "
                f"{mesh.shape[axis]} (use backend='threads' or 'vmap' on "
                f"hosts with fewer devices)")
        fns: dict[int, object] = {}  # jitted super-step per round count
        r0 = 0
        while r0 < ps.n_rounds:
            sc = ctl.next()
            rounds = list(range(r0, min(r0 + sc, ps.n_rounds)))
            blocks = [ps.round_at(r, *extras) for r in rounds]
            # (S, R, B) lane-major blocks for this super-chunk
            src_b = jnp.stack([b[0] for b in blocks], axis=1)
            dst_b = jnp.stack([b[1] for b in blocks], axis=1)
            nv_b = jnp.stack([b[2] for b in blocks], axis=1)
            exs_b = tuple(
                jnp.stack([b[3][j] for b in blocks], axis=1)
                for j in range(len(extras)))
            R = len(rounds)
            if R not in fns:
                fns[R] = _make_super_step(pc, mesh, axis, R, base,
                                          len(extras))
            prev = base
            base, parts_b = fns[R](prev, src_b, dst_b, nv_b, *exs_b)
            base = jax.tree_util.tree_map(lambda x: x[0], base)
            ctl.observe(prev, base)
            if pc.emits_parts:
                for ri, r in enumerate(rounds):
                    ids = blocks[ri][4]
                    for s, cid in enumerate(ids):
                        if cid is not None:
                            parts_by_chunk[cid] = parts_b[s, ri]
            r0 += sc
    elif backend == "threads":
        # S host workers fold their sub-streams concurrently through the
        # shared compiled step (execution releases the GIL); chunk staging
        # is serialized under one lock — the out-of-core stream's budget
        # accounting and staging buffers are not thread-safe, and staging
        # is a small fraction of a chunk's scan cost.
        stage_lock = threading.Lock()
        # lanes are mutable here: straggler handoff re-deals remaining
        # chunks between merge boundaries (the sharding plan's own lists
        # stay pristine in the chunk-granular modes; hub mode re-registers
        # synthetic chunks, pin map updated in place)
        lanes = [list(lane) for lane in ps.lanes]
        pos = [0] * S  # per-lane cursor into its (possibly re-dealt) list
        edges_done = 0  # edges committed through merges (checkpoint key)
        consumer = (carry_consumer if carry_consumer is not None
                    else f"parallel:{type(pc).__name__}")
        store_cfg = dict(carry_config or {})
        store_cfg.setdefault("super_chunk", str(super_chunk))
        store_cfg.setdefault("shard", shard)

        def lane_fold(lane_id, chunks, start, inject):
            local = start
            t0 = time.perf_counter()
            for cid in chunks:
                if inject is not None:
                    inject.check(lane_id, cid)
                with stage_lock:
                    ch = ps.chunk_for(cid, *extras)
                local, parts = pc.step_chunk(
                    local, ch.src, ch.dst, jnp.int32(ch.n_valid), *ch.extras)
                if parts is not None:
                    parts_by_chunk[cid] = parts[: ch.n_valid]
            return local, time.perf_counter() - t0

        def save_base(carry_val):
            if carry_store is not None:
                carry_store.save(carry_val, consumer=consumer,
                                 config=store_cfg, stream_pos=edges_done)

        def restore_base():
            if carry_store is None:
                return base  # in-memory merge base == last commit point
            restored, _ = carry_store.load(like=base, consumer=consumer,
                                           config=store_cfg,
                                           max_stream_pos=edges_done)
            return restored

        save_base(base)  # a lane can die before the first merge commits
        sc_index = 0
        with ThreadPoolExecutor(max_workers=S) as ex:
            while any(pos[s] < len(lanes[s]) for s in range(S)):
                sc = ctl.next()
                batches = [lanes[s][pos[s]:pos[s] + sc] for s in range(S)]
                futs = [ex.submit(lane_fold, s, batches[s], base,
                                  lane_injector) for s in range(S)]
                locals_: list = [None] * S
                times = [0.0] * S
                failed: list[int] = []
                for s, f in enumerate(futs):
                    try:
                        locals_[s], times[s] = f.result()
                    except Exception as e:  # noqa: BLE001 — lane death
                        if on_lane_failure != "replay":
                            raise
                        log.warning("ingest lane %d died mid-super-chunk "
                                    "(%s); replaying its range", s, e)
                        failed.append(s)
                for s in failed:
                    # replay the dead lane's chunk range from the last
                    # committed base into a surviving worker — the merge
                    # below can't tell the difference (bit-identical)
                    locals_[s], times[s] = ex.submit(
                        lane_fold, s, batches[s], restore_base(),
                        None).result()
                prev = base
                base = pc.merge(locals_, base=prev)
                ctl.observe(prev, base)
                edges_done += sum(ps.chunk_n_valid(cid)
                                  for b in batches for cid in b)
                for s in range(S):
                    pos[s] += len(batches[s])
                    lane_chunks[s] += len(batches[s])
                    lane_edges[s] += sum(ps.chunk_n_valid(c)
                                         for c in batches[s])
                    lane_wall[s] += times[s]
                save_base(base)
                if straggler is not None:
                    for s in range(S):
                        if batches[s]:
                            # per-chunk time: lane *speed*, not workload
                            straggler.record(sc_index,
                                             times[s] / len(batches[s]),
                                             shard=s)
                    _handoff_lanes(ps, lanes, pos, straggler)
                sc_index += 1
    else:
        raise ValueError(f"unknown backend {backend!r}")

    if backend != "threads":  # lanes execute as one program per round
        wall = time.perf_counter() - t_run
        for s in range(S):
            lane_chunks[s] = len(ps.lanes[s])
            lane_edges[s] = sum(ps.chunk_n_valid(c) for c in ps.lanes[s])
            lane_wall[s] = wall
    merges = len(ctl.schedule)
    _publish_stats(pc, IngestStats(
        num_streams=S, shard=shard, backend=backend, super_chunk=super_chunk,
        schedule=tuple(ctl.schedule),
        lanes=tuple(LaneStats(chunks=lane_chunks[s], edges=lane_edges[s],
                              merge_count=merges, wall_s=lane_wall[s])
                    for s in range(S))))

    result = pc.finalize(base)
    if not parts_by_chunk:
        return None, result
    if ps.shard == "hub":
        # synthetic chunks carry their stream positions; scatter each
        # folded chunk's results straight to position order
        first = next(iter(parts_by_chunk.values()))
        out = np.empty((stream.n_edges,), np.asarray(first).dtype)
        for cid, p in parts_by_chunk.items():
            posns = ps._chunk_pos[cid]
            out[posns] = np.asarray(p)[: len(posns)]
        return stream.scatter_back(jnp.asarray(out)), result
    outs = [parts_by_chunk[cid][: ps.chunk_n_valid(cid)]
            for cid in range(stream.n_chunks)]
    parts = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
    return stream.scatter_back(parts), result


def _handoff_lanes(ps, lanes, pos, straggler):
    """Live lane-range handoff at a merge boundary: ask the monitor's
    :meth:`rebalance_plan` what tail cut each straggler lane should give
    up, and physically move those chunk ids to the receiving lane's
    queue.  Chunks already folded (before ``pos``) never move.  In hub
    mode the cut is re-sliced at whole-hub boundaries — every remaining
    edge of a moved hub moves together and the plan's ``pin_map`` is
    updated — so hub pinning survives the handoff."""
    ranges = [(pos[s], len(lanes[s])) for s in range(len(lanes))]
    plan = straggler.rebalance_plan(ranges)
    if plan == ranges:
        return
    if ps.shard == "hub":
        _handoff_lanes_hub(ps, lanes, pos, ranges, plan)
        return
    moved: list[int] = []
    receiver = None
    for s, ((_, hi_old), (_, hi_new)) in enumerate(zip(ranges, plan)):
        if hi_new < hi_old:
            cut = hi_old - hi_new
            moved.extend(lanes[s][len(lanes[s]) - cut:])
            del lanes[s][len(lanes[s]) - cut:]
        elif hi_new > hi_old:
            receiver = s
    if receiver is not None and moved:
        # keep stream order within the receiving lane's tail
        lanes[receiver].extend(sorted(moved))
        log.info("straggler handoff: %d chunk(s) moved to lane %d",
                 len(moved), receiver)


def _handoff_lanes_hub(ps, lanes, pos, ranges, plan):
    """Hub-granular handoff: re-slice each straggler's remaining *edges*
    at a whole-hub boundary (a hub edge moves iff its hub's first
    remaining occurrence is past the boundary — so a hub's remaining
    edges either all stay or all move, in stream order either way),
    re-register both sides as fresh synthetic chunks, and move the moved
    hubs' ``pin_map`` entries to the receiver."""
    B = ps.stream.chunk_size
    receiver = None
    for s, ((_, hi_old), (_, hi_new)) in enumerate(zip(ranges, plan)):
        if hi_new > hi_old:
            receiver = s
    if receiver is None:
        return
    for s, ((_, hi_old), (_, hi_new)) in enumerate(zip(ranges, plan)):
        cut = hi_old - hi_new
        if cut <= 0 or s == receiver:
            continue
        rest = lanes[s][pos[s]:]
        if not rest:
            continue
        positions = np.concatenate([ps._chunk_pos[c] for c in rest])
        boundary = max(len(positions) - cut * B, 0)
        pv = ps._pin_vertex[positions]
        idx = np.arange(len(positions))
        move = (pv < 0) & (idx >= boundary)
        hub_ids, first = np.unique(pv[pv >= 0], return_index=True)
        # first occurrence per hub within the remaining edges: positions
        # are ascending, so np.unique's first index is the earliest
        hub_first = np.full(len(positions), -1, np.int64)
        if hub_ids.size:
            starts = np.flatnonzero(pv >= 0)
            # map each hub edge to its hub's first remaining index
            order = np.argsort(pv[starts], kind="stable")
            # simpler: dict lookup (hub counts are small by construction)
            first_of = {int(h): int(np.flatnonzero(pv == h)[0])
                        for h in hub_ids}
            for i in starts:
                move[i] = first_of[int(pv[i])] >= boundary
        keep_pos = positions[~move]
        move_pos = positions[move]
        if not move_pos.size:
            continue
        lanes[s] = lanes[s][:pos[s]] + ps._register_chunks(keep_pos)
        lanes[receiver].extend(ps._register_chunks(move_pos))
        moved_hubs = np.unique(pv[move & (pv >= 0)])
        for h in moved_hubs:
            ps.pin_map[int(h)] = receiver
        ps._lane_of_pos[move_pos] = receiver
        log.info("straggler handoff (hub): %d edge(s), %d hub pin(s) "
                 "moved lane %d → %d", len(move_pos), len(moved_hubs), s,
                 receiver)


def _make_super_step(pc, mesh, axis, R, base, n_ex):
    """Build the jitted shard_map super-step for R rounds: each device
    folds its lane's R chunks from the replicated base carry, then the
    carries are merged by one collective per field.  Returns a callable
    ``(base, src (S,R,B), dst, nv (S,R), *extras) -> (merged (S-stacked,
    identical per lane — caller takes lane 0), parts (S, R, B))``."""
    P = jax.sharding.PartitionSpec
    lane = P(axis)

    step = _mask_inactive_step(pc)

    def body(base_carry, src, dst, nv, *exs):
        local = jax.tree_util.tree_map(
            lambda x: _pvary(x, (axis,)), base_carry)
        parts_rounds = []
        for r in range(R):
            local, parts = step(
                local, src[0, r], dst[0, r], nv[0, r],
                *[e[0, r] for e in exs])
            if pc.emits_parts:
                parts_rounds.append(parts)
        merged = pc.merge_collective(local, base_carry, axis)
        merged = jax.tree_util.tree_map(lambda x: x[None], merged)
        if parts_rounds:
            return merged, jnp.stack(parts_rounds)[None]
        return merged, jnp.zeros((1, 1, 1), jnp.int32)

    return jax.jit(_shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: P(), base),
                  lane, lane, lane) + (lane,) * n_ex,
        out_specs=(jax.tree_util.tree_map(lambda _: lane, base), lane),
    ))
