from .pipeline import TokenPipeline, RecsysPipeline, Prefetcher  # noqa: F401
