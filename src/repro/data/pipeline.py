"""Step-addressable synthetic data pipelines + host prefetch.

Every pipeline is a pure function of (seed, step) — the property the
fault-tolerant loop relies on for bitwise resume: replaying step s after a
restart yields the identical batch, so optimizer state trajectories match
exactly (tested).

``Prefetcher`` overlaps host batch synthesis with device compute (the
standard double-buffering trick; on real hardware this hides input
latency — one of the distributed-optimization items of DESIGN.md §5).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenPipeline", "RecsysPipeline", "EdgeChunkPipeline", "Prefetcher"]


class TokenPipeline:
    """Zipf-distributed token batches (LM pretraining stand-in)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 zipf_a: float = 1.2):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.zipf_a = zipf_a

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.zipf(self.zipf_a, (self.batch, self.seq + 1)) % self.vocab
        toks = toks.astype(np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:])}


class RecsysPipeline:
    """Click-log batches: power-law feature ids + logistic labels."""

    def __init__(self, vocabs: tuple[int, ...], batch: int, seed: int = 0):
        self.vocabs, self.batch, self.seed = vocabs, batch, seed

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        cols = []
        for v in self.vocabs:
            z = rng.zipf(1.3, self.batch) % v
            cols.append(z.astype(np.int32))
        ids = np.stack(cols, axis=1)
        # labels correlate with a fixed random hash of field 0 (learnable)
        h = (ids[:, 0] * 2654435761 % 97) / 97.0
        labels = (rng.random(self.batch) < 0.15 + 0.5 * h).astype(np.float32)
        return {"field_ids": jnp.asarray(ids), "labels": jnp.asarray(labels)}


class EdgeChunkPipeline:
    """Step-addressable edge-chunk feed over an :class:`EdgeStream`.

    ``step`` indexes chunks modulo the stream (wrapping = one replay pass
    per epoch), so the fault-tolerant loop's bitwise-resume contract holds:
    replaying step s yields the identical chunk.  Compose with
    :class:`Prefetcher` to overlap host chunking with device scans.
    """

    def __init__(self, src, dst, n_vertices: int, *, chunk_size: int = 1 << 16,
                 ordering: str = "natural", seed: int = 0):
        from ..streaming import EdgeStream

        self.stream = EdgeStream(src, dst, n_vertices, chunk_size=chunk_size,
                                 ordering=ordering, seed=seed)

    def __call__(self, step: int) -> dict:
        # chunks are index-addressable — only the requested one is built
        # (bounded device footprint, the streaming package's contract)
        nc = self.stream.n_chunks
        ch = self.stream.chunk_at(step % nc)
        return {"src": ch.src, "dst": ch.dst, "start": ch.start,
                "n_valid": ch.n_valid, "epoch": step // nc}


class Prefetcher:
    """Double-buffered host → device prefetch around any step-addressable fn."""

    def __init__(self, fn: Callable[[int], dict], depth: int = 2):
        self.fn = fn
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = 0
        self._thread: threading.Thread | None = None

    def start(self, start_step: int = 0) -> None:
        self._next = start_step
        self._stop = False

        def work():
            s = start_step
            while not self._stop:
                self._q.put((s, self.fn(s)))
                s += 1

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __call__(self, step: int) -> dict:
        if self._thread is None:
            return self.fn(step)
        while True:
            s, batch = self._q.get()
            if s == step:
                return batch
            # restart/seek: fall back to direct synthesis
            if s > step:
                return self.fn(step)

    def stop(self):
        self._stop = True
