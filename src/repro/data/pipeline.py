"""Step-addressable synthetic data pipelines + host prefetch.

Every pipeline is a pure function of (seed, step) — the property the
fault-tolerant loop relies on for bitwise resume: replaying step s after a
restart yields the identical batch, so optimizer state trajectories match
exactly (tested).

``Prefetcher`` overlaps host batch synthesis with device compute (the
standard double-buffering trick; on real hardware this hides input
latency — one of the distributed-optimization items of DESIGN.md §5).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenPipeline", "RecsysPipeline", "EdgeChunkPipeline", "Prefetcher"]


class TokenPipeline:
    """Zipf-distributed token batches (LM pretraining stand-in)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 zipf_a: float = 1.2):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.zipf_a = zipf_a

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.zipf(self.zipf_a, (self.batch, self.seq + 1)) % self.vocab
        toks = toks.astype(np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:])}


class RecsysPipeline:
    """Click-log batches: power-law feature ids + logistic labels."""

    def __init__(self, vocabs: tuple[int, ...], batch: int, seed: int = 0):
        self.vocabs, self.batch, self.seed = vocabs, batch, seed

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        cols = []
        for v in self.vocabs:
            z = rng.zipf(1.3, self.batch) % v
            cols.append(z.astype(np.int32))
        ids = np.stack(cols, axis=1)
        # labels correlate with a fixed random hash of field 0 (learnable)
        h = (ids[:, 0] * 2654435761 % 97) / 97.0
        labels = (rng.random(self.batch) < 0.15 + 0.5 * h).astype(np.float32)
        return {"field_ids": jnp.asarray(ids), "labels": jnp.asarray(labels)}


class EdgeChunkPipeline:
    """Step-addressable edge-chunk feed over an :class:`EdgeStream`.

    ``step`` indexes chunks modulo the stream (wrapping = one replay pass
    per epoch), so the fault-tolerant loop's bitwise-resume contract holds:
    replaying step s yields the identical chunk.  Compose with
    :class:`Prefetcher` to overlap host chunking — or, for out-of-core
    streams, disk paging — with device scans.

    The first argument may be a per-edge ``src`` array (classic form, with
    ``dst``/``n_vertices`` following), an already-built stream (any
    :class:`EdgeStream` subclass), or a shard-manifest path / ``file:<path>``
    spec, which opens a mmap-paged :class:`ShardedEdgeStream`.
    """

    def __init__(self, src, dst=None, n_vertices: int | None = None, *,
                 chunk_size: int = 1 << 16, ordering: str = "natural",
                 seed: int = 0, window: int = 4096):
        from pathlib import Path

        from ..streaming import EdgeStream, ShardedEdgeStream

        if isinstance(src, EdgeStream):
            if dst is not None or n_vertices is not None:
                raise ValueError("pass either a stream or (src, dst, n_vertices)")
            self.stream = src
        elif isinstance(src, (str, Path)):
            manifest = str(src)
            manifest = manifest[5:] if manifest.startswith("file:") else manifest
            if dst is not None or n_vertices is not None:
                raise ValueError("pass either a manifest path or (src, dst, n_vertices)")
            self.stream = ShardedEdgeStream(manifest, chunk_size=chunk_size,
                                            ordering=ordering, seed=seed,
                                            window=window)
        else:
            self.stream = EdgeStream(src, dst, n_vertices, chunk_size=chunk_size,
                                     ordering=ordering, seed=seed, window=window)

    def __call__(self, step: int) -> dict:
        # chunks are index-addressable — only the requested one is built
        # (bounded device footprint, the streaming package's contract)
        nc = self.stream.n_chunks
        ch = self.stream.chunk_at(step % nc)
        return {"src": ch.src, "dst": ch.dst, "start": ch.start,
                "n_valid": ch.n_valid, "epoch": step // nc}


class Prefetcher:
    """Double-buffered host → device prefetch around any step-addressable fn.

    ``stop()`` really terminates the worker: the producer only ever blocks
    in ``put`` with a timeout (re-checking the stop flag), and ``stop``
    drains the queue until the thread exits — so no daemon-thread leak and
    ``start``/``stop``/``start`` cycles are safe (each ``start`` gets a
    fresh queue, discarding stale entries from the previous run).  A
    worker that dies in ``fn`` re-raises in the consumer instead of
    leaving it blocked on an empty queue forever.
    """

    _FAILED = object()  # queue sentinel: the worker died in fn

    def __init__(self, fn: Callable[[int], dict], depth: int = 2):
        self.fn = fn
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = True
        self._error: BaseException | None = None
        self._thread: threading.Thread | None = None

    def start(self, start_step: int = 0) -> None:
        self.stop()  # terminate any previous worker before rewiring
        self._stop = False
        self._error = None
        q = self._q = queue.Queue(maxsize=self.depth)

        def put_until_stopped(item) -> bool:
            while not self._stop:
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def work():
            s = start_step
            try:
                while not self._stop:
                    batch = (s, self.fn(s))
                    if put_until_stopped(batch):
                        s += 1
            except BaseException as e:  # surface in the consumer
                self._error = e
                put_until_stopped((s, self._FAILED))

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __call__(self, step: int) -> dict:
        if self._thread is None:
            return self.fn(step)
        while True:
            try:
                s, batch = self._q.get(timeout=0.1)
            except queue.Empty:
                if self._error is not None:
                    raise RuntimeError(
                        "prefetch worker died") from self._error
                if not self._thread.is_alive():
                    return self.fn(step)  # worker gone without error: direct
                continue
            if batch is self._FAILED:
                raise RuntimeError(
                    f"prefetch worker died at step {s}") from self._error
            if s == step:
                return batch
            # restart/seek: fall back to direct synthesis
            if s > step:
                return self.fn(step)

    def stop(self):
        self._stop = True
        t = self._thread
        if t is None:
            return
        while t.is_alive():
            try:  # unblock a producer waiting on a full queue
                self._q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=0.05)
        self._thread = None
