"""Flash attention in pure jnp with a custom VJP (recompute backward).

The forward scans KV in blocks with online softmax (never materializing
S×T scores); the backward follows the FlashAttention-2 recipe — save only
(out, lse), recompute per-block scores, accumulate dq/dk/dv blockwise.
Without the custom VJP, JAX's scan AD would stash every block's
probabilities (O(S·T) residuals ⇒ tens of GiB per device at 4k–32k).

Supports causal masking, sliding windows (Mixtral), GQA head groups, and
arbitrary per-token positions (rolling decode caches).  This is also the
numerical oracle for ``repro.kernels.flash_attention`` (same dataflow the
Pallas kernel implements with BlockSpec VMEM tiles).

Shapes: q (B, S, H, hd); k/v (B, T, KV, hd); H = KV·G.
Positions: q_pos (B, S); kv_pos (B, T); kv_pos < 0 ⇒ masked (padding).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["flash_attention", "decode_attention"]

_NEG = -1e30


def _mask(q_pos_blk, kv_pos_blk, causal, window):
    """(Bq, Sq) × (B, Tc) → (B, Sq, Tc) bool."""
    dp = q_pos_blk[:, :, None] - kv_pos_blk[:, None, :]
    ok = kv_pos_blk[:, None, :] >= 0
    if causal:
        ok = ok & (dp >= 0)
    if window is not None:
        ok = ok & (dp < window)
    return ok


def _fwd_qblock(qb, k, v, qp, kvp, causal, window, kv_chunk):
    """One q block against all kv chunks.  qb: (B, Sq, KV, G, hd)."""
    B, Sq, KV, G, hd = qb.shape
    T = k.shape[1]
    nkv = T // kv_chunk
    kc = k.reshape(B, nkv, kv_chunk, KV, hd)
    vc = v.reshape(B, nkv, kv_chunk, KV, hd)
    pc = kvp.reshape(B, nkv, kv_chunk)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, pb = blk
        s = jnp.einsum("bskgh,btkh->bkgst", qb, kb,
                       preferred_element_type=jnp.float32)
        ok = _mask(qp, pb, causal, window)  # (B, Sq, Tc)
        s = jnp.where(ok[:, None, None, :, :], s, _NEG)
        new_m = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - new_m[..., None])
        corr = jnp.exp(m - new_m)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkh->bkgsh", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (new_m, l, acc), ()

    m0 = jnp.full((B, KV, G, Sq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         pc.transpose(1, 0, 2)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,G,Sq,hd)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (B,KV,G,Sq)
    return out, lse


def _flash_fwd(q, k, v, q_pos, kv_pos, causal, window, q_chunk, kv_chunk):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    # pad T to kv_chunk
    T = k.shape[1]
    padt = (-T) % kv_chunk
    if padt:
        k = jnp.pad(k, ((0, 0), (0, padt), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padt), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, padt)), constant_values=-(2**30))
    # pad S to q_chunk
    pads = (-S) % q_chunk
    if pads:
        q = jnp.pad(q, ((0, 0), (0, pads), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pads)), constant_values=0)
    Sp = q.shape[1]
    nq = Sp // q_chunk
    qg = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qg = qg.reshape(B, nq, q_chunk, KV, G, hd)
    qp = q_pos.reshape(B, nq, q_chunk)

    def qstep(_, blk):
        qb, qpb = blk
        out, lse = _fwd_qblock(qb, k, v, qpb, kv_pos, causal, window, kv_chunk)
        return (), (out, lse)

    _, (out, lse) = jax.lax.scan(
        qstep, (), (qg.transpose(1, 0, 2, 3, 4, 5), qp.transpose(1, 0, 2))
    )
    # out: (nq, B, KV, G, qc, hd) → (B, S, H, hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, H, hd)[:, :S]
    lse = lse.transpose(1, 0, 4, 2, 3).reshape(B, Sp, KV, G)[:, :S]
    return out.astype(q.dtype), lse


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def flash_attention(q, k, v, q_pos, kv_pos, causal=True, window=None,
                    q_chunk=512, kv_chunk=1024):
    out, _ = _flash_fwd(q, k, v, q_pos, kv_pos, causal, window, q_chunk, kv_chunk)
    return out


def _fa_fwd(q, k, v, q_pos, kv_pos, causal, window, q_chunk, kv_chunk):
    out, lse = _flash_fwd(q, k, v, q_pos, kv_pos, causal, window, q_chunk, kv_chunk)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _fa_bwd(causal, window, q_chunk, kv_chunk, res, g):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    # delta_i = Σ_h do_i · o_i  (per query, per head)
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    delta = delta.reshape(B, S, KV, G)

    padt = (-T) % kv_chunk
    if padt:
        k = jnp.pad(k, ((0, 0), (0, padt), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padt), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, padt)), constant_values=-(2**30))
    pads = (-S) % q_chunk
    if pads:
        pad4 = ((0, 0), (0, pads), (0, 0), (0, 0))
        q = jnp.pad(q, pad4)
        g = jnp.pad(g, pad4)
        lse = jnp.pad(lse, ((0, 0), (0, pads), (0, 0), (0, 0)),
                      constant_values=1.0)
        delta = jnp.pad(delta, ((0, 0), (0, pads), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pads)), constant_values=-(2**30))
    Sp, Tp = q.shape[1], k.shape[1]
    nq, nkv = Sp // q_chunk, Tp // kv_chunk

    qs = q.reshape(B, nq, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    gs = g.reshape(B, nq, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    lses = lse.reshape(B, nq, q_chunk, KV, G).transpose(1, 0, 2, 3, 4)
    deltas = delta.reshape(B, nq, q_chunk, KV, G).transpose(1, 0, 2, 3, 4)
    qps = q_pos.reshape(B, nq, q_chunk).transpose(1, 0, 2)
    kc = k.reshape(B, nkv, kv_chunk, KV, hd)
    vc = v.reshape(B, nkv, kv_chunk, KV, hd)
    pc = kv_pos.reshape(B, nkv, kv_chunk)

    def qstep(carry, blk):
        dk, dv = carry  # (B, Tp, KV, hd) fp32
        qb, gb, lseb, db, qpb = blk

        def kvstep(dq_acc, j):
            kb = jax.lax.dynamic_slice_in_dim(kc, j, 1, axis=1)[:, 0]
            vb = jax.lax.dynamic_slice_in_dim(vc, j, 1, axis=1)[:, 0]
            pb = jax.lax.dynamic_slice_in_dim(pc, j, 1, axis=1)[:, 0]
            s = jnp.einsum("bskgh,btkh->bkgst", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            ok = _mask(qpb, pb, causal, window)
            s = jnp.where(ok[:, None, None, :, :], s, _NEG)
            p = jnp.exp(s - lseb.transpose(0, 2, 3, 1)[..., None])  # (B,KV,G,Sq,Tc)
            dp = jnp.einsum("bskgh,btkh->bkgst", gb, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - db.transpose(0, 2, 3, 1)[..., None]) * scale
            dqb = jnp.einsum("bkgst,btkh->bskgh", ds.astype(kb.dtype), kb,
                             preferred_element_type=jnp.float32)
            dkb = jnp.einsum("bkgst,bskgh->btkh", ds.astype(qb.dtype), qb,
                             preferred_element_type=jnp.float32)
            dvb = jnp.einsum("bkgst,bskgh->btkh", p.astype(gb.dtype), gb,
                             preferred_element_type=jnp.float32)
            return dq_acc + dqb, (dkb, dvb, j)

        dq0 = jnp.zeros(qb.shape, jnp.float32)
        dq, (dks, dvs, _) = jax.lax.scan(kvstep, dq0, jnp.arange(nkv))
        # scatter kv-chunk grads back
        dk = dk + dks.transpose(1, 0, 2, 3, 4).reshape(B, Tp, KV, hd)
        dv = dv + dvs.transpose(1, 0, 2, 3, 4).reshape(B, Tp, KV, hd)
        return (dk, dv), dq

    dk0 = jnp.zeros((B, Tp, KV, hd), jnp.float32)
    dv0 = jnp.zeros((B, Tp, KV, hd), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(qstep, (dk0, dv0),
                                 (qs, gs, lses, deltas, qps))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, H, hd)[:, :S]
    dk = dk[:, :T].astype(k.dtype)
    dv = dv[:, :T].astype(v.dtype)
    return dq.astype(q.dtype), dk, dv, None, None


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def decode_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None):
    """Single-query attention against a (possibly rolling) cache.

    q: (B, 1, H, hd); k/v: (B, T, KV, hd); direct einsum — the (B, H, T)
    score tensor is small at decode shapes, and a T-sharded cache turns the
    softmax into a flash-decoding split-K reduction under SPMD.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd) * (hd ** -0.5)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k, preferred_element_type=jnp.float32)
    ok = _mask(q_pos, kv_pos, causal, window)
    s = jnp.where(ok[:, None, None, :, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, hd).astype(q.dtype)
