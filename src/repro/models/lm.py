"""Decoder-only LM family: Qwen2.5 / Llama-3 / Qwen3 (dense) + Mixtral (MoE).

One implementation parameterized by :class:`LMConfig` covers all five
assigned architectures:

- GQA attention with RoPE, optional QKV bias (Qwen2.5), optional qk-norm
  (Qwen3), optional sliding-window attention (Mixtral);
- SwiGLU dense MLP or top-2 MoE (Mixtral) with sort-based capacity dispatch;
- layers stacked and scanned (``lax.scan``) with per-layer remat — compile
  time and HLO size stay O(1) in depth;
- attention is **blocked** (online-softmax over KV chunks, flash-attention
  dataflow in pure jnp) so 32k-sequence cells never materialize S×S scores.
  The Pallas TPU kernel (``repro.kernels.flash_attention``) implements the
  same contract for the hot path; ``use_flash_kernel`` switches it on.

Sharding: logical-axis annotations only (see repro/sharding.py) — the same
model code runs single-device tests and the 512-chip dry-run.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .attention import decode_attention, flash_attention
from .common import dense_init, rms_norm, softmax_xent

__all__ = ["LMConfig", "init_params", "forward", "loss_fn", "init_cache",
           "prefill", "decode_step", "count_params", "model_flops"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    sliding_window: int | None = None  # SWA width (Mixtral)
    onehot_embed: bool = True  # vocab-sharded lookup as a contraction (§Perf)
    n_experts: int = 0  # 0 ⇒ dense MLP
    top_k: int = 2
    capacity_factor: float = 1.25
    dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024  # KV block for the online-softmax scan
    remat: bool = True
    use_flash_kernel: bool = False

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_params(cfg: LMConfig, key) -> dict:
    L, D, H, KV, hd, F, V = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                             cfg.n_kv_heads, cfg.d_head, cfg.d_ff, cfg.vocab)
    ks = jax.random.split(key, 16)
    dt = cfg.dtype

    def w(key, *shape, scale=None):
        return dense_init(key, shape, scale=scale, dtype=dt)

    attn = {
        "wq": w(ks[0], L, D, H * hd),
        "wk": w(ks[1], L, D, KV * hd),
        "wv": w(ks[2], L, D, KV * hd),
        "wo": w(ks[3], L, H * hd, D),
    }
    if cfg.qkv_bias:
        attn["bq"] = jnp.zeros((L, H * hd), dt)
        attn["bk"] = jnp.zeros((L, KV * hd), dt)
        attn["bv"] = jnp.zeros((L, KV * hd), dt)
    if cfg.qk_norm:
        attn["q_norm"] = jnp.ones((L, hd), dt)
        attn["k_norm"] = jnp.ones((L, hd), dt)

    if cfg.is_moe:
        E = cfg.n_experts
        mlp = {
            "router": w(ks[4], L, D, E, scale=0.02),
            "w_gate": w(ks[5], L, E, D, F),
            "w_up": w(ks[6], L, E, D, F),
            "w_down": w(ks[7], L, E, F, D),
        }
    else:
        mlp = {
            "w_gate": w(ks[5], L, D, F),
            "w_up": w(ks[6], L, D, F),
            "w_down": w(ks[7], L, F, D),
        }

    return {
        "embed": w(ks[8], V, D, scale=0.02),
        "layers": {
            "attn": attn,
            "mlp": mlp,
            "ln1": jnp.ones((L, D), dt),
            "ln2": jnp.ones((L, D), dt),
        },
        "final_norm": jnp.ones((D,), dt),
        "lm_head": w(ks[9], D, V, scale=0.02),
    }


def count_params(cfg: LMConfig) -> int:
    L, D, H, KV, hd, F, V = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                             cfg.n_kv_heads, cfg.d_head, cfg.d_ff, cfg.vocab)
    attn = L * (D * H * hd + 2 * D * KV * hd + H * hd * D)
    if cfg.is_moe:
        mlp = L * (D * cfg.n_experts + cfg.n_experts * 3 * D * F)
    else:
        mlp = L * 3 * D * F
    return attn + mlp + 2 * V * D + L * 2 * D + D


def active_params(cfg: LMConfig) -> int:
    """Per-token active parameters (MoE counts top_k experts only)."""
    if not cfg.is_moe:
        return count_params(cfg)
    L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    total = count_params(cfg)
    moe_all = L * cfg.n_experts * 3 * D * F
    moe_act = L * cfg.top_k * 3 * D * F
    return total - moe_all + moe_act


def model_flops(cfg: LMConfig, n_tokens: int, train: bool = True) -> float:
    """6·N_active·D (train) or 2·N_active·D (inference) + attention term."""
    n = active_params(cfg)
    mult = 6.0 if train else 2.0
    return mult * n * n_tokens


# ---------------------------------------------------------------------------
# rotary embedding
# ---------------------------------------------------------------------------


def _rope(x, positions, theta):
    """x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]  # (B, S, 1, half) — broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (sort-based capacity dispatch — Mixtral top-2)
# ---------------------------------------------------------------------------


def _moe_block(x_bsd, mp, cfg: LMConfig):
    """x_bsd: (B, S, D).  Returns (B, S, D), aux load-balance loss.

    **Group-limited** sort-based dispatch: each batch row is its own
    dispatch group (rows are sharded over (data, model), so the argsort,
    capacity ranking, and (E, cap, D) dispatch buffers are all
    device-local — a global dispatch would replicate an O(T·D) buffer on
    every chip).  Within a group, tokens are ranked per expert; each
    expert serves up to C = cf·top_k·S/E of the row's tokens (overflow
    dropped — GShard/Mixtral-style).  Expert weights carry the
    ("expert", "fsdp", "mlp") layout — EP when the expert axis divides
    the mesh, TP otherwise.
    """
    from ..sharding import logical_spec

    spec = logical_spec("batch")
    axes = spec[0] if len(spec) and spec[0] else None
    out, aux = jax.vmap(
        lambda xr: _moe_dispatch_group(xr, mp, cfg), spmd_axis_name=axes
    )(x_bsd)
    return out, jnp.mean(aux)


def _moe_dispatch_group(x_flat, mp, cfg: LMConfig):
    """One dispatch group: x_flat (T, D) → (T, D), aux."""
    T, D = x_flat.shape
    E, K = cfg.n_experts, cfg.top_k
    F = cfg.d_ff
    cap = int(cfg.capacity_factor * K * T / E)
    cap = max(8, min(cap, T))

    logits = jnp.einsum("td,de->te", x_flat, mp["router"].astype(x_flat.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, K)  # (T, K)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # aux load-balance loss (Switch): E · Σ_e f_e · p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_e, E, dtype=jnp.float32), axis=1), axis=0
    ) / K
    aux = E * jnp.sum(me * ce)

    # rank tokens within each expert by (expert, arrival) sort
    flat_e = gate_e.reshape(-1)  # (T·K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    # position within expert = index − start(expert)
    idx = jnp.arange(T * K, dtype=jnp.int32)
    starts = jnp.searchsorted(e_sorted, jnp.arange(E, dtype=jnp.int32))
    pos_in_e = idx - starts[e_sorted]
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, E * cap)  # sink slot
    # dispatch: (E·cap + 1, D).  Every big intermediate is pinned to the
    # vmapped group axis (vmap spmd_axis_name prepends the batch mesh axes)
    # so the SPMD partitioner can never replicate the dispatch buffers.
    gathered = constrain(x_flat[flat_t[order]], None, None)
    buf = jnp.zeros((E * cap + 1, D), x_flat.dtype)
    buf = buf.at[slot].set(gathered)
    xe = buf[: E * cap].reshape(E, cap, D)
    xe = constrain(xe, None, None, None)
    # grouped expert GEMMs
    g = jnp.einsum("ecd,edf->ecf", xe, mp["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, mp["w_up"])
    h = jax.nn.silu(g) * u
    h = constrain(h, None, None, None)
    ye = jnp.einsum("ecf,efd->ecd", h, mp["w_down"])
    ye = constrain(ye, None, None, None)
    # combine: weighted scatter-add back to tokens
    yflat = ye.reshape(E * cap, D)
    contrib = jnp.where(keep[:, None], yflat[jnp.minimum(slot, E * cap - 1)], 0.0)
    contrib = constrain(contrib, None, None)
    out = jnp.zeros((T, D), x_flat.dtype)
    out = out.at[flat_t[order]].add(contrib * flat_w[order][:, None].astype(x_flat.dtype))
    out = constrain(out, None, None)
    return out, aux


# ---------------------------------------------------------------------------
# transformer layer + scan
# ---------------------------------------------------------------------------


def _attention_block(x, ap, cfg: LMConfig, positions, cache=None, layer_cache=None):
    """Full-seq path when ``layer_cache`` is None; else one-token decode."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dh->bsh", x, ap["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, ap["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, ap["wv"])
    if cfg.qkv_bias:
        q = q + ap["bq"]
        k = k + ap["bk"]
        v = v + ap["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, ap["q_norm"])
        k = rms_norm(k, ap["k_norm"])
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", None, None)

    if layer_cache is None:
        out = flash_attention(
            q, k, v, positions, positions,
            True, cfg.sliding_window,
            min(cfg.attn_chunk // 2, max(S, 8)), min(cfg.attn_chunk, max(S, 8)),
        )
        # prefill stacks these per layer — pin the cache to the kv_seq
        # layout so the (L, B, S, KV, hd) stack is model-axis sharded
        # (unused+DCE'd in the training path)
        new_cache = (constrain(k, "batch", "kv_seq", None, None),
                     constrain(v, "batch", "kv_seq", None, None))
    else:
        ck, cv, cpos = layer_cache  # (B, Smax, KV, hd) ×2, (B, Smax)
        # rolling write for SWA caches; plain append otherwise
        Smax = ck.shape[1]
        wpos = positions[:, 0] % Smax  # (B,)
        bidx = jnp.arange(B)
        ck = ck.at[bidx, wpos].set(k[:, 0])
        cv = cv.at[bidx, wpos].set(v[:, 0])
        cpos = cpos.at[bidx, wpos].set(positions[:, 0])
        ck = constrain(ck, "batch", "kv_seq", None, None)
        cv = constrain(cv, "batch", "kv_seq", None, None)
        out = decode_attention(
            q, ck, cv, positions, cpos,
            causal=True, window=cfg.sliding_window,
        )
        new_cache = (ck, cv, cpos)

    out = out.reshape(B, S, H * hd)
    out = jnp.einsum("bsh,hd->bsd", out, ap["wo"])
    return constrain(out, "batch", "seq", None), new_cache


def _layer(x, lp, cfg: LMConfig, positions, layer_cache=None):
    h, new_cache = _attention_block(
        rms_norm(x, lp["ln1"]), lp["attn"], cfg, positions, layer_cache=layer_cache
    )
    x = x + h
    y = rms_norm(x, lp["ln2"])
    if cfg.is_moe:
        out, aux = _moe_block(y, lp["mlp"], cfg)
    else:
        g = jnp.einsum("bsd,df->bsf", y, lp["mlp"]["w_gate"])
        u = jnp.einsum("bsd,df->bsf", y, lp["mlp"]["w_up"])
        hmid = jax.nn.silu(g) * u
        hmid = constrain(hmid, "batch", "seq", "mlp")
        out = jnp.einsum("bsf,fd->bsd", hmid, lp["mlp"]["w_down"])
        aux = jnp.float32(0.0)
    x = x + constrain(out, "batch", "seq", None)
    return x, aux, new_cache


def _embed(params, tokens, cfg: LMConfig):
    """Token embedding.  The one-hot contraction keeps the vocab-sharded
    table local (each shard contracts its vocab slice + psum) — a plain
    gather makes the SPMD partitioner replicate the table AND its f32
    gradient on every chip (§Perf hillclimb 1, confirmed ~10× whale)."""
    if not cfg.onehot_embed:
        return params["embed"].astype(cfg.dtype)[tokens]
    onehot = jax.nn.one_hot(tokens, cfg.vocab, dtype=cfg.dtype)
    return jnp.einsum("bsv,vd->bsd", onehot, params["embed"].astype(cfg.dtype))


def forward(params, tokens, cfg: LMConfig, positions=None):
    """Training/prefill forward: (B, S) → logits (B, S, V), aux, kv caches."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _embed(params, tokens, cfg)
    # Train cells map "batch" to (data, model) and "seq" to pod — tokens are
    # fully sharded over all 512 chips, so the per-layer remat stash (the
    # scan carry saved for backward) is structurally 512-way sharded.
    x = constrain(x, "batch", "seq", None)

    def body(carry, lp):
        x, aux = carry
        x, a, _ = _layer(x, lp, cfg, positions)
        x = constrain(x, "batch", "seq", None)
        return (x, aux + a), ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"])
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = constrain(logits, "batch", "seq", "mlp")
    return logits, aux / cfg.n_layers


def loss_fn(params, batch, cfg: LMConfig):
    tokens, targets = batch["tokens"], batch["targets"]
    logits, aux = forward(params, tokens, cfg)
    loss = softmax_xent(logits, targets)
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with (rolling) KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_seq: int) -> dict:
    """SWA models roll within a window-sized cache."""
    S = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((L, batch, S, KV, hd), cfg.dtype),
        "v": jnp.zeros((L, batch, S, KV, hd), cfg.dtype),
        "pos": jnp.full((L, batch, S), -1, jnp.int32),
    }


def prefill(params, tokens, cfg: LMConfig, max_seq: int):
    """Forward the prompt, returning last-position logits + a filled cache."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = constrain(x, "batch", None, None)
    caches_k, caches_v = [], []

    def body(carry, lp):
        x = carry
        x, _, cache = _layer(x, lp, cfg, positions)
        return x, cache

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(body_fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["lm_head"])
    # pack the trailing window into the rolling cache layout
    cache = init_cache(cfg, B, max_seq)
    W = cache["k"].shape[2]
    take = min(W, S)
    sl = slice(S - take, S)
    if take == W and (S - take) % W == 0:
        # scatter-free fast path: slot i holds position S−W+i exactly
        cache["k"] = ks[:, :, sl]
        cache["v"] = vs[:, :, sl]
        cache["pos"] = jnp.broadcast_to(positions[None, :, sl],
                                        (cfg.n_layers, B, W)).astype(jnp.int32)
        return logits, cache
    idx = positions[:, sl] % W  # (B, take)
    bidx = jnp.arange(B)[:, None]
    cache["k"] = cache["k"].at[:, bidx, idx].set(ks[:, :, sl])
    cache["v"] = cache["v"].at[:, bidx, idx].set(vs[:, :, sl])
    cache["pos"] = cache["pos"].at[:, bidx, idx].set(positions[:, sl])
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: LMConfig):
    """One decode step: tokens (B,), pos (B,) → logits (B, V), new cache."""
    B = tokens.shape[0]
    positions = pos[:, None]  # (B, 1)
    x = params["embed"].astype(cfg.dtype)[tokens[:, None]]  # B tokens: gather is cheap

    def body(x, layer):
        lp, ck, cv, cpos = layer
        x, _, new_cache = _layer(x, lp, cfg, positions, layer_cache=(ck, cv, cpos))
        return x, new_cache

    x, (ck, cv, cpos) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["pos"])
    )
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["lm_head"])
    return logits, {"k": ck, "v": cv, "pos": cpos}
