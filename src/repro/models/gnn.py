"""Assigned GNN architectures on the segment-sum message-passing substrate.

JAX has no sparse-matmul beyond BCOO, so message passing is implemented the
TPU-native way (DESIGN.md): gather source features by ``edge_src``,
transform, ``jax.ops.segment_sum`` into destinations.  That substrate *is*
part of the system — the same edge-index layout S5P partitions, so a
distributed run shards edges by partition and the replica ``psum`` volume
is exactly RF-driven (see repro/gas).

Architectures (exact assigned configs live in repro/configs/):
- **GCN**     2-layer, symmetric-normalized SpMM               [Kipf 2017]
- **SchNet**  continuous-filter convolutions over RBF(d_ij)    [Schütt 2017]
- **EGNN**    E(n)-equivariant layers (scalar messages +
              coordinate updates)                              [Satorras 2021]
- **DimeNet** directional message passing on edges with
              radial/spherical bases + triplet aggregation     [Gasteiger 2020]

All models share a flat-graph interface: node features / positions +
``edge_src``/``edge_dst`` (+ ``edge_mask`` for padded minibatches), and a
``triplets`` index list for DimeNet (edge→edge adjacency, precomputed by
the data pipeline exactly like reference implementations do).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .common import dense_init, softmax_xent

__all__ = ["GCNConfig", "SchNetConfig", "EGNNConfig", "DimeNetConfig"]


def _seg_sum(x, idx, n):
    return jax.ops.segment_sum(x, idx, num_segments=n)


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": dense_init(ks[i], (dims[i], dims[i + 1]), dtype=dtype),
         "b": jnp.zeros((dims[i + 1],), dtype)}
        for i in range(len(dims) - 1)
    ]


def _mlp_apply(layers, x, act=jax.nn.silu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# GCN (gcn-cora: 2 layers, d_hidden 16, mean/sym aggregation)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    n_layers: int = 2
    d_hidden: int = 16
    d_feat: int = 1433
    n_classes: int = 7
    dtype: Any = jnp.float32


def gcn_init(cfg: GCNConfig, key):
    ks = jax.random.split(key, cfg.n_layers)
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    return {
        "layers": [
            {"w": dense_init(ks[i], (dims[i], dims[i + 1]), dtype=cfg.dtype)}
            for i in range(cfg.n_layers)
        ]
    }


def gcn_forward(params, feats, edge_src, edge_dst, n_nodes, cfg: GCNConfig,
                edge_mask=None):
    """Symmetric-normalized GCN: H' = D^-½ Ã D^-½ H W (self-loops included)."""
    ones = jnp.ones_like(edge_src, dtype=cfg.dtype)
    if edge_mask is not None:
        ones = ones * edge_mask
    deg = _seg_sum(ones, edge_dst, n_nodes) + _seg_sum(ones, edge_src, n_nodes) + 1.0
    inv_sqrt = jax.lax.rsqrt(deg)
    x = feats.astype(cfg.dtype)
    for i, layer in enumerate(params["layers"]):
        x = x @ layer["w"]
        x = constrain(x, "nodes", None)
        norm_msg = x[edge_src] * (inv_sqrt[edge_src] * inv_sqrt[edge_dst])[:, None]
        if edge_mask is not None:
            norm_msg = norm_msg * edge_mask[:, None]
        agg = _seg_sum(norm_msg, edge_dst, n_nodes)
        # symmetrize (undirected) + normalized self loop
        rev = x[edge_dst] * (inv_sqrt[edge_src] * inv_sqrt[edge_dst])[:, None]
        if edge_mask is not None:
            rev = rev * edge_mask[:, None]
        agg = agg + _seg_sum(rev, edge_src, n_nodes)
        x = agg + x * inv_sqrt[:, None] ** 2
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
    return x


def gcn_loss(params, batch, cfg: GCNConfig):
    logits = gcn_forward(
        params, batch["feats"], batch["edge_src"], batch["edge_dst"],
        batch["feats"].shape[0], cfg, batch.get("edge_mask"),
    )
    mask = batch.get("label_mask")
    return softmax_xent(logits, batch["labels"], mask), {}


# ---------------------------------------------------------------------------
# SchNet (n_interactions=3, d_hidden=64, rbf=300, cutoff=10)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100
    dtype: Any = jnp.float32


def schnet_init(cfg: SchNetConfig, key):
    ks = jax.random.split(key, 2 + 3 * cfg.n_interactions)
    d = cfg.d_hidden
    params = {
        "embed": dense_init(ks[0], (cfg.n_species, d), scale=1.0, dtype=cfg.dtype),
        "inter": [],
        "out": _mlp_init(ks[1], [d, d // 2, 1], cfg.dtype),
    }
    for i in range(cfg.n_interactions):
        params["inter"].append({
            "filter": _mlp_init(ks[2 + 3 * i], [cfg.n_rbf, d, d], cfg.dtype),
            "in_w": dense_init(ks[3 + 3 * i], (d, d), dtype=cfg.dtype),
            "post": _mlp_init(ks[4 + 3 * i], [d, d, d], cfg.dtype),
        })
    return params


def _rbf_expand(dist, n_rbf, cutoff):
    centers = jnp.linspace(0.0, cutoff, n_rbf, dtype=jnp.float32)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers))


def _ssp(x):  # shifted softplus, SchNet's activation
    return jax.nn.softplus(x) - jnp.log(2.0)


def schnet_forward(params, species, positions, edge_src, edge_dst, n_nodes,
                   cfg: SchNetConfig, edge_mask=None, node_mask=None,
                   graph_idx=None, n_graphs=1):
    """Energy prediction.  Flat node arrays; batched molecules use graph_idx."""
    x = params["embed"][species]
    d = jnp.linalg.norm(positions[edge_src] - positions[edge_dst] + 1e-9, axis=-1)
    rbf = _rbf_expand(d, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)
    cosc = 0.5 * (jnp.cos(jnp.pi * d / cfg.cutoff) + 1.0)  # smooth cutoff
    cosc = jnp.where(d <= cfg.cutoff, cosc, 0.0).astype(cfg.dtype)
    if edge_mask is not None:
        cosc = cosc * edge_mask
    for blk in params["inter"]:
        w_ij = _mlp_apply(blk["filter"], rbf, act=_ssp) * cosc[:, None]
        h = x @ blk["in_w"]
        msg = h[edge_src] * w_ij
        agg = _seg_sum(msg, edge_dst, n_nodes)
        agg = constrain(agg, "nodes", None)
        x = x + _mlp_apply(blk["post"], agg, act=_ssp)
    e_atom = _mlp_apply(params["out"], x, act=_ssp)[:, 0]
    if node_mask is not None:
        e_atom = e_atom * node_mask
    if graph_idx is None:
        return jnp.sum(e_atom, keepdims=True)
    return _seg_sum(e_atom, graph_idx, n_graphs)


def schnet_loss(params, batch, cfg: SchNetConfig):
    pred = schnet_forward(
        params, batch["species"], batch["positions"], batch["edge_src"],
        batch["edge_dst"], batch["species"].shape[0], cfg,
        batch.get("edge_mask"), batch.get("node_mask"),
        batch.get("graph_idx"), batch.get("n_graphs", 1),
    )
    err = pred - batch["targets"]
    return jnp.mean(jnp.square(err)), {"mae": jnp.mean(jnp.abs(err))}


# ---------------------------------------------------------------------------
# EGNN (n_layers=4, d_hidden=64, E(n)-equivariant)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    n_layers: int = 4
    d_hidden: int = 64
    n_species: int = 100
    dtype: Any = jnp.float32


def egnn_init(cfg: EGNNConfig, key):
    ks = jax.random.split(key, 1 + 4 * cfg.n_layers)
    d = cfg.d_hidden
    params = {
        "embed": dense_init(ks[0], (cfg.n_species, d), scale=1.0, dtype=cfg.dtype),
        "layers": [],
        "out": _mlp_init(ks[-1], [d, d, 1], cfg.dtype),
    }
    for i in range(cfg.n_layers):
        params["layers"].append({
            "phi_e": _mlp_init(ks[1 + 4 * i], [2 * d + 1, d, d], cfg.dtype),
            "phi_x": _mlp_init(ks[2 + 4 * i], [d, d, 1], cfg.dtype),
            "phi_h": _mlp_init(ks[3 + 4 * i], [2 * d, d, d], cfg.dtype),
        })
    return params


def egnn_forward(params, species, positions, edge_src, edge_dst, n_nodes,
                 cfg: EGNNConfig, edge_mask=None, node_mask=None,
                 graph_idx=None, n_graphs=1):
    h = params["embed"][species]
    x = positions.astype(jnp.float32)
    for blk in params["layers"]:
        diff = x[edge_src] - x[edge_dst]
        d2 = jnp.sum(jnp.square(diff), axis=-1, keepdims=True)
        m = _mlp_apply(blk["phi_e"], jnp.concatenate(
            [h[edge_src], h[edge_dst], d2.astype(cfg.dtype)], axis=-1), final_act=True)
        if edge_mask is not None:
            m = m * edge_mask[:, None]
        # coordinate update (C=1/(deg) normalization via mean)
        coef = _mlp_apply(blk["phi_x"], m)  # (E,1)
        ones = edge_mask if edge_mask is not None else jnp.ones_like(
            edge_src, dtype=jnp.float32)
        cnt = _seg_sum(ones, edge_dst, n_nodes) + 1.0
        dx = _seg_sum(diff * coef.astype(jnp.float32), edge_dst, n_nodes)
        x = x + dx / cnt[:, None]
        # feature update
        agg = _seg_sum(m, edge_dst, n_nodes)
        agg = constrain(agg, "nodes", None)
        h = h + _mlp_apply(blk["phi_h"], jnp.concatenate([h, agg], axis=-1))
    e_atom = _mlp_apply(params["out"], h)[:, 0]
    if node_mask is not None:
        e_atom = e_atom * node_mask
    if graph_idx is None:
        return jnp.sum(e_atom, keepdims=True)
    return _seg_sum(e_atom, graph_idx, n_graphs)


def egnn_loss(params, batch, cfg: EGNNConfig):
    pred = egnn_forward(
        params, batch["species"], batch["positions"], batch["edge_src"],
        batch["edge_dst"], batch["species"].shape[0], cfg,
        batch.get("edge_mask"), batch.get("node_mask"),
        batch.get("graph_idx"), batch.get("n_graphs", 1),
    )
    err = pred - batch["targets"]
    return jnp.mean(jnp.square(err)), {"mae": jnp.mean(jnp.abs(err))}


# ---------------------------------------------------------------------------
# DimeNet (n_blocks=6, d_hidden=128, bilinear=8, spherical=7, radial=6)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 100
    dtype: Any = jnp.float32


def dimenet_init(cfg: DimeNetConfig, key):
    ks = jax.random.split(key, 4 + 5 * cfg.n_blocks)
    d = cfg.d_hidden
    params = {
        "embed": dense_init(ks[0], (cfg.n_species, d), scale=1.0, dtype=cfg.dtype),
        "rbf_w": dense_init(ks[1], (cfg.n_radial, d), dtype=cfg.dtype),
        "edge_mlp": _mlp_init(ks[2], [3 * d, d], cfg.dtype),
        "blocks": [],
        "out": _mlp_init(ks[3], [d, d, 1], cfg.dtype),
    }
    nsph = cfg.n_spherical * cfg.n_radial
    for i in range(cfg.n_blocks):
        params["blocks"].append({
            "w_src": dense_init(ks[4 + 5 * i], (d, d), dtype=cfg.dtype),
            "sbf_w": dense_init(ks[5 + 5 * i], (nsph, cfg.n_bilinear), dtype=cfg.dtype),
            "bilinear": dense_init(ks[6 + 5 * i], (cfg.n_bilinear, d, d),
                                   scale=0.1, dtype=cfg.dtype),
            "post": _mlp_init(ks[7 + 5 * i], [d, d, d], cfg.dtype),
        })
    return params


def _bessel_rbf(d, n_radial, cutoff):
    """DimeNet's spherical Bessel radial basis j0(nπd/c)."""
    dn = jnp.maximum(d, 1e-6) / cutoff
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * dn[:, None]) / d[:, None]


def _angular_sbf(angle, d, n_spherical, n_radial, cutoff):
    """Simplified spherical basis: cos(l·θ) ⊗ Bessel_n(d) — the same rank
    structure as DimeNet's 2D basis (Legendre×Bessel), TPU-cheap."""
    l = jnp.arange(n_spherical, dtype=jnp.float32)
    ang = jnp.cos(angle[:, None] * (l + 1.0))  # (T, n_sph)
    rad = _bessel_rbf(d, n_radial, cutoff)  # (T, n_rad)
    return (ang[:, :, None] * rad[:, None, :]).reshape(angle.shape[0], -1)


def dimenet_forward(params, species, positions, edge_src, edge_dst,
                    tri_kj, tri_ji, n_nodes, cfg: DimeNetConfig,
                    edge_mask=None, tri_mask=None, node_mask=None,
                    graph_idx=None, n_graphs=1):
    """Directional message passing.

    Messages live on directed edges m_ji (j→i).  Triplet lists give, for
    each pair (edge kj, edge ji) sharing vertex j, the indices
    ``tri_kj`` / ``tri_ji`` — aggregation sums transformed m_kj into m_ji
    weighted by the angular basis of ∠(k,j,i).
    """
    E = edge_src.shape[0]
    vec = positions[edge_src] - positions[edge_dst]
    d = jnp.linalg.norm(vec + 1e-9, axis=-1)
    rbf = _bessel_rbf(d, cfg.n_radial, cfg.cutoff).astype(cfg.dtype)
    # triplet geometry: angle between edge kj and ji at shared vertex j
    v1 = vec[tri_kj]
    v2 = -vec[tri_ji]
    cosang = jnp.sum(v1 * v2, axis=-1) / (
        jnp.linalg.norm(v1 + 1e-9, axis=-1) * jnp.linalg.norm(v2 + 1e-9, axis=-1))
    angle = jnp.arccos(jnp.clip(cosang, -1.0 + 1e-6, 1.0 - 1e-6))
    sbf = _angular_sbf(angle, d[tri_kj], cfg.n_spherical, cfg.n_radial,
                       cfg.cutoff).astype(cfg.dtype)
    if tri_mask is not None:
        sbf = sbf * tri_mask[:, None]

    h = params["embed"][species]
    rbf_d = rbf @ params["rbf_w"]
    m = _mlp_apply(params["edge_mlp"], jnp.concatenate(
        [h[edge_src], h[edge_dst], rbf_d], axis=-1), final_act=True)
    if edge_mask is not None:
        m = m * edge_mask[:, None]

    out_e = jnp.zeros((n_nodes, cfg.d_hidden), cfg.dtype)
    for blk in params["blocks"]:
        # directional aggregation: m_ji ← Σ_k sbf·W[m_kj] (bilinear form)
        src_t = (m @ blk["w_src"])[tri_kj]  # (T, d)
        sb = sbf @ blk["sbf_w"]  # (T, n_bilinear)
        inter = jnp.einsum("tb,bdf,td->tf", sb, blk["bilinear"], src_t)
        agg = _seg_sum(inter, tri_ji, E)
        agg = constrain(agg, "edges", None)
        m = m + _mlp_apply(blk["post"], agg, final_act=True)
        if edge_mask is not None:
            m = m * edge_mask[:, None]
        out_e = out_e + _seg_sum(rbf_d * m, edge_dst, n_nodes)

    e_atom = _mlp_apply(params["out"], out_e)[:, 0]
    if node_mask is not None:
        e_atom = e_atom * node_mask
    if graph_idx is None:
        return jnp.sum(e_atom, keepdims=True)
    return _seg_sum(e_atom, graph_idx, n_graphs)


def dimenet_loss(params, batch, cfg: DimeNetConfig):
    pred = dimenet_forward(
        params, batch["species"], batch["positions"], batch["edge_src"],
        batch["edge_dst"], batch["tri_kj"], batch["tri_ji"],
        batch["species"].shape[0], cfg,
        batch.get("edge_mask"), batch.get("tri_mask"), batch.get("node_mask"),
        batch.get("graph_idx"), batch.get("n_graphs", 1),
    )
    err = pred - batch["targets"]
    return jnp.mean(jnp.square(err)), {"mae": jnp.mean(jnp.abs(err))}


def build_triplets(edge_src, edge_dst, max_triplets: int):
    """Host-side triplet index construction: pairs (kj, ji) sharing j.

    Returns (tri_kj, tri_ji, tri_mask) padded to ``max_triplets`` — part of
    the data pipeline, mirroring reference DimeNet preprocessing.
    """
    import numpy as np

    edge_src = np.asarray(edge_src)
    edge_dst = np.asarray(edge_dst)
    by_dst: dict[int, list[int]] = {}
    for e, dv in enumerate(edge_dst):
        by_dst.setdefault(int(dv), []).append(e)
    kj, ji = [], []
    for e_ji, j in enumerate(edge_src):
        for e_kj in by_dst.get(int(j), ()):  # edges k→j
            if edge_src[e_kj] == edge_dst[e_ji]:
                continue  # exclude k == i backtrack
            kj.append(e_kj)
            ji.append(e_ji)
            if len(kj) >= max_triplets:
                break
        if len(kj) >= max_triplets:
            break
    n = len(kj)
    tri_kj = np.zeros(max_triplets, np.int32)
    tri_ji = np.zeros(max_triplets, np.int32)
    mask = np.zeros(max_triplets, np.float32)
    tri_kj[:n] = kj
    tri_ji[:n] = ji
    mask[:n] = 1.0
    return tri_kj, tri_ji, mask
