"""Shared layer primitives (pure-jnp; parameters are plain pytrees)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dense_init", "rms_norm", "layer_norm", "swish", "gelu", "softmax_xent"]


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM inits)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = 1.0 / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * scale).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


def swish(x):
    return x * jax.nn.sigmoid(x)


gelu = jax.nn.gelu


def softmax_xent(logits, labels, mask=None):
    """Mean CE over valid positions; numerically-stable fp32 reduction."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
