from . import lm, gnn, recsys  # noqa: F401
