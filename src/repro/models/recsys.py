"""xDeepFM: sparse embeddings + CIN feature interaction + deep MLP.

[Lian et al., arXiv:1803.05170]  Assigned config: 39 sparse fields,
embed_dim 10, CIN 200-200-200, MLP 400-400.

JAX has no ``nn.EmbeddingBag`` or CSR sparse — the lookup substrate here is
built from ``jnp.take`` + ``jax.ops.segment_sum`` (the brief calls this out
as part of the system):

- :func:`embedding_lookup` — one-hot fields, row-sharded tables;
- :func:`embedding_bag`    — multi-hot ragged bags (sum/mean), used by the
  user-history field variant and exercised by tests;
- :func:`s5p_row_placement` — the paper's technique applied to the
  embedding tables: the (sample × feature-row) bipartite access graph is
  power-law, so S5P's vertex-cut replicates *hot* rows across shards and
  single-homes the tail — reducing lookup all-to-all volume exactly like
  replica-aware production placements.

The CIN layer (outer product + contraction) is the compute hot spot; the
Pallas kernel lives in ``repro.kernels.cin`` and this file keeps the jnp
path (identical math) as default/reference.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import constrain
from .common import dense_init

__all__ = ["XDeepFMConfig", "xdeepfm_init", "xdeepfm_forward", "xdeepfm_loss",
           "embedding_lookup", "embedding_bag", "s5p_row_placement",
           "retrieval_scores"]


@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    n_fields: int = 39
    embed_dim: int = 10
    cin_layers: tuple[int, ...] = (200, 200, 200)
    mlp_dims: tuple[int, ...] = (400, 400)
    # heterogeneous vocab sizes: a few huge fields + many small (Criteo-like)
    field_vocabs: tuple[int, ...] = ()
    dtype: Any = jnp.float32

    def vocabs(self) -> tuple[int, ...]:
        # powers of two so row-sharded tables divide any mesh axis exactly
        if self.field_vocabs:
            return self.field_vocabs
        out = []
        for i in range(self.n_fields):
            if i % 13 == 0:
                out.append(1_048_576)
            elif i % 5 == 0:
                out.append(131_072)
            elif i % 3 == 0:
                out.append(16_384)
            else:
                out.append(1_024)
        return tuple(out)


# ---------------------------------------------------------------------------
# embedding substrate (JAX-native EmbeddingBag)
# ---------------------------------------------------------------------------


def embedding_lookup(table, indices):
    """Row gather; table carries the ("rows", None) sharding."""
    return jnp.take(table, indices, axis=0)


def embedding_bag(table, indices, offsets, mode: str = "sum"):
    """torch.nn.EmbeddingBag semantics via gather + segment_sum.

    indices: (N,) flat row ids; offsets: (B,) bag starts.  Returns (B, D).
    """
    n = indices.shape[0]
    bag_ids = jnp.searchsorted(offsets, jnp.arange(n, dtype=offsets.dtype),
                               side="right") - 1
    rows = jnp.take(table, indices, axis=0)
    out = jax.ops.segment_sum(rows, bag_ids, num_segments=offsets.shape[0])
    if mode == "mean":
        sizes = jax.ops.segment_sum(jnp.ones((n,), table.dtype), bag_ids,
                                    num_segments=offsets.shape[0])
        out = out / jnp.maximum(sizes, 1.0)[:, None]
    return out


def s5p_row_placement(access_rows: np.ndarray, access_samples: np.ndarray,
                      n_rows: int, k: int, **s5p_kwargs):
    """Place embedding rows on k shards with S5P over the bipartite access
    graph (samples ∪ rows).  Returns (row_shard (n_rows,), replica_mask
    (n_rows, k)) — head (hot) rows come back replicated on several shards.
    """
    from ..core import S5PConfig, s5p_partition
    from ..core.metrics import replica_matrix

    n_samples = int(access_samples.max()) + 1 if access_samples.size else 1
    src = np.asarray(access_samples, np.int64)
    dst = np.asarray(access_rows, np.int64) + n_samples  # rows after samples
    cfg = S5PConfig(k=k, **s5p_kwargs)
    out = s5p_partition(src.astype(np.int32), dst.astype(np.int32),
                        n_samples + n_rows, cfg)
    mat = np.asarray(replica_matrix(
        jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32), out.parts,
        n_vertices=n_samples + n_rows, k=k,
    ))[n_samples:]
    shard = np.where(mat.any(1), mat.argmax(1), np.arange(n_rows) % k)
    return shard.astype(np.int32), mat


# ---------------------------------------------------------------------------
# xDeepFM
# ---------------------------------------------------------------------------


def xdeepfm_init(cfg: XDeepFMConfig, key):
    vocabs = cfg.vocabs()
    ks = jax.random.split(key, len(vocabs) + len(cfg.cin_layers) + len(cfg.mlp_dims) + 4)
    D, m = cfg.embed_dim, cfg.n_fields
    tables = [
        dense_init(ks[i], (v, D), scale=0.01, dtype=cfg.dtype) for i, v in enumerate(vocabs)
    ]
    lin_tables = [jnp.zeros((v, 1), cfg.dtype) for v in vocabs]
    j = len(vocabs)
    cin = []
    h_prev = m
    for h in cfg.cin_layers:
        cin.append(dense_init(ks[j], (h_prev * m, h), scale=0.1, dtype=cfg.dtype))
        h_prev = h
        j += 1
    mlp = []
    d_in = m * D
    for d_out in cfg.mlp_dims:
        mlp.append({
            "w": dense_init(ks[j], (d_in, d_out), dtype=cfg.dtype),
            "b": jnp.zeros((d_out,), cfg.dtype),
        })
        d_in = d_out
        j += 1
    return {
        "tables": tables,
        "lin_tables": lin_tables,
        "cin": cin,
        "cin_out": dense_init(ks[j], (sum(cfg.cin_layers), 1), dtype=cfg.dtype),
        "mlp": mlp,
        "mlp_out": dense_init(ks[j + 1], (d_in, 1), dtype=cfg.dtype),
        "bias": jnp.zeros((1,), cfg.dtype),
    }


def _cin_layer(x_k, x_0, w):
    """One CIN layer: z = outer(x_k, x_0) along fields, 1×1-conv compress.

    x_k: (B, Hk, D); x_0: (B, m, D); w: (Hk·m, Hk+1) → (B, Hk+1, D).
    The jnp reference for kernels/cin.
    """
    B, Hk, D = x_k.shape
    m = x_0.shape[1]
    z = jnp.einsum("bhd,bmd->bhmd", x_k, x_0)  # (B, Hk, m, D)
    z = z.reshape(B, Hk * m, D)
    return jnp.einsum("bzd,zh->bhd", z, w)


def xdeepfm_forward(params, field_ids, cfg: XDeepFMConfig):
    """field_ids: (B, n_fields) int32 per-field row indices → logits (B,)."""
    B = field_ids.shape[0]
    embs = []
    lin = jnp.zeros((B, 1), cfg.dtype)
    for f in range(cfg.n_fields):
        t = constrain(params["tables"][f], "rows", None)
        embs.append(embedding_lookup(t, field_ids[:, f]))
        lin = lin + embedding_lookup(params["lin_tables"][f], field_ids[:, f])
    x0 = jnp.stack(embs, axis=1)  # (B, m, D)
    x0 = constrain(x0, "batch", None, None)

    # CIN branch
    xk = x0
    pools = []
    for w in params["cin"]:
        xk = _cin_layer(xk, x0, w)
        xk = constrain(xk, "batch", "mlp", None)
        pools.append(jnp.sum(xk, axis=-1))  # (B, Hk)
    cin_logit = jnp.concatenate(pools, axis=-1) @ params["cin_out"]

    # deep branch
    h = x0.reshape(B, -1)
    for layer in params["mlp"]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
        h = constrain(h, "batch", "mlp")
    deep_logit = h @ params["mlp_out"]

    return (lin + cin_logit + deep_logit + params["bias"])[:, 0]


def xdeepfm_loss(params, batch, cfg: XDeepFMConfig):
    logits = xdeepfm_forward(params, batch["field_ids"], cfg)
    y = batch["labels"].astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"logloss": loss}


def retrieval_scores(params, query_ids, cand_table, cfg: XDeepFMConfig, top_k: int = 100):
    """retrieval_cand shape: one query scored against N candidates.

    Query tower: pooled field embeddings; candidates: (N, D) table (row-
    sharded).  Batched dot + top-k — no per-candidate loop.
    """
    embs = [embedding_lookup(params["tables"][f], query_ids[:, f])
            for f in range(cfg.n_fields)]
    q = jnp.mean(jnp.stack(embs, axis=1), axis=1)  # (B, D)
    cand = constrain(cand_table, "rows", None)
    scores = jnp.einsum("bd,nd->bn", q, cand)
    return jax.lax.top_k(scores, top_k)
